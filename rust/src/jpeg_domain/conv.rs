//! JPEG-domain convolution (paper §4.1).
//!
//! `jpeg_conv_dcc` is the decompress-convolve-compress composition — the
//! paper's eq. 11 evaluated without materializing Xi; "mathematically
//! equivalent ... not an approximation" (paper §3.2).  `explode_conv`
//! materializes the block-local Xi (Algorithm 1), mirroring
//! `python/compile/layers.py`.
//!
//! ## Gather-free sparse formulation vs. Algorithm 1
//!
//! Algorithm 1 applies Xi by *gathering* each output block's 3x3 block
//! neighborhood into a `(N*Bho*Bwo, 9*C*64)` matrix and multiplying it
//! by Xi — a dense formulation that materializes every zero the
//! quantizer produced and every zero-padding border block.  The default
//! path here inverts that: for each output block it walks only the
//! *stored nonzeros* of the 9 neighboring input blocks (via
//! [`SparseBlocks`]) and accumulates `value x Xi-row` into the output
//! row.  Because `y_row = sum_k a[row,k] * Xi[k,:]` is a sum of scaled
//! Xi rows, dropping the zero terms is exact, not an approximation —
//! the arithmetic that remains is identical to Algorithm 1's.  Border
//! neighborhoods that fall outside the image contribute nothing and are
//! skipped outright instead of being gathered as zero blocks.  The
//! dense Algorithm-1 path is kept as [`jpeg_conv_exploded_dense`] so
//! dense-vs-sparse stays a measured ablation (see
//! `bench_harness::throughput::sparse_conv_ablation`).

use crate::tensor::{conv2d, matmul, matmul_tiled, SparseBlocks, Tensor};

use super::{decode_tensor, encode_tensor};

/// Decompress -> conv (fixed padding convention) -> compress.
pub fn jpeg_conv_dcc(f: &Tensor, w: &Tensor, qvec: &[f32; 64], stride: usize) -> Tensor {
    let x = decode_tensor(f, qvec);
    let y = conv2d(&x, w, stride);
    encode_tensor(&y, qvec)
}

/// Materialize the block-local exploded map: (9 * Cin * 64, Cout * 64).
///
/// Built by pushing all 9*64 basis blocks of a 3x3 block neighborhood
/// through decompress -> conv -> window-extract -> compress; see
/// DESIGN.md for the window-offset derivation per (ksize, stride).
pub fn explode_conv(w: &Tensor, qvec: &[f32; 64], stride: usize) -> Tensor {
    let (cout, cin, kh) = (w.shape()[0], w.shape()[1], w.shape()[2]);
    // output-block window offset within the 24x24 neighborhood's VALID conv
    let off = match (kh, stride) {
        (3, 1) => 7,
        (1, 1) => 8,
        (3, 2) | (1, 2) => 0,
        _ => panic!("unsupported conv ({kh}, {stride})"),
    };

    let dec = super::dec_matrix(qvec);
    let enc = super::enc_matrix(qvec);

    // single-plane kernels, hoisted out of the 9*64 basis loop
    let kernels: Vec<Tensor> = (0..cout * cin)
        .map(|i| {
            let (co, ci) = (i / cin, i % cin);
            let mut wk = Tensor::zeros(&[1, 1, kh, kh]);
            for a in 0..kh {
                let row = w.slice_at(&[co, ci, a], kh).to_vec();
                wk.copy_block(&[0, 0, a], &row);
            }
            wk
        })
        .collect();

    let mut xi = Tensor::zeros(&[9 * cin * 64, cout * 64]);
    // basis pixel images of each coefficient (64 pixels per coefficient)
    for delta in 0..9 {
        let (dy, dx) = (delta / 3, delta % 3);
        for k in 0..64 {
            // decompressed basis block for coefficient k, placed at
            // (dy, dx) inside a 24x24 neighborhood image
            let pix = dec.slice_at(&[k], 64).to_vec();
            let mut img = Tensor::zeros(&[1, 1, 24, 24]);
            for y in 0..8 {
                img.copy_block(&[0, 0, dy * 8 + y, dx * 8], &pix[y * 8..y * 8 + 8]);
            }
            for co in 0..cout {
                for ci in 0..cin {
                    let resp = valid_conv_plane(&img, &kernels[co * cin + ci], stride);
                    // extract the 8x8 output window and compress
                    let mut win = [0.0f32; 64];
                    for y in 0..8 {
                        win[y * 8..y * 8 + 8]
                            .copy_from_slice(resp.slice_at(&[0, 0, off + y, off], 8));
                    }
                    let wt = Tensor::from_vec(&[1, 64], win.to_vec());
                    let fz = matmul(&wt, &enc);
                    // each (row, co) pair is visited exactly once
                    let row = (delta * cin + ci) * 64 + k;
                    xi.slice_at_mut(&[row], cout * 64)[co * 64..(co + 1) * 64]
                        .copy_from_slice(fz.data());
                }
            }
        }
    }
    xi
}

/// VALID (no padding) single-image conv used by the explode builder.
fn valid_conv_plane(x: &Tensor, w: &Tensor, stride: usize) -> Tensor {
    let (h, width) = (x.shape()[2], x.shape()[3]);
    let k = w.shape()[2];
    let oh = (h - k) / stride + 1;
    let ow = (width - k) / stride + 1;
    let xd = x.data();
    let wd = w.data();
    let mut out = vec![0.0f32; oh * ow];
    for oy in 0..oh {
        for ox in 0..ow {
            let mut acc = 0.0f32;
            for ky in 0..k {
                let xrow = &xd[(oy * stride + ky) * width + ox * stride..][..k];
                let wrow = &wd[ky * k..][..k];
                acc += xrow.iter().zip(wrow).map(|(a, b)| a * b).sum::<f32>();
            }
            out[oy * ow + ox] = acc;
        }
    }
    Tensor::from_vec(&[1, 1, oh, ow], out)
}

/// Output block grid for a given stride.
#[inline]
fn out_blocks(bh: usize, bw: usize, stride: usize) -> (usize, usize) {
    if stride == 1 {
        (bh, bw)
    } else {
        (bh / 2, bw / 2)
    }
}

/// Input block coordinate of neighborhood slot `delta` for output block
/// (oy, ox), or `None` when the slot falls in the zero padding.
/// Stride 1: neighborhood centered (origin oy-1); stride 2: anchored at
/// 2*oy.
#[inline]
fn neighbor(
    oy: usize,
    ox: usize,
    delta: usize,
    stride: usize,
    bh: usize,
    bw: usize,
) -> Option<(usize, usize)> {
    let (dy, dx) = ((delta / 3) as isize, (delta % 3) as isize);
    let (iy, ix) = if stride == 1 {
        (oy as isize + dy - 1, ox as isize + dx - 1)
    } else {
        (2 * oy as isize + dy, 2 * ox as isize + dx)
    };
    if iy < 0 || ix < 0 || iy >= bh as isize || ix >= bw as isize {
        None
    } else {
        Some((iy as usize, ix as usize))
    }
}

/// Reorder row-major conv output rows `(N*Bho*Bwo, Cout*out_cut)` into
/// the coefficient layout `(N, Cout, Bho, Bwo, 64)` with block-slice
/// copies.  `out_cut < 64` means the rows came from a column-trimmed Xi
/// (see [`band_limit_xi`]); the untouched high-band coefficients stay
/// exactly zero.
fn rows_to_coeff_tensor(
    rows: &[f32],
    n: usize,
    cout: usize,
    bho: usize,
    bwo: usize,
    out_cut: usize,
) -> Tensor {
    let xw = cout * out_cut;
    let mut res = vec![0.0f32; n * cout * bho * bwo * 64];
    for b in 0..n {
        for oy in 0..bho {
            for ox in 0..bwo {
                let src = &rows[((b * bho + oy) * bwo + ox) * xw..][..xw];
                for co in 0..cout {
                    let dst = ((((b * cout + co) * bho) + oy) * bwo + ox) * 64;
                    res[dst..dst + out_cut].copy_from_slice(&src[co * out_cut..][..out_cut]);
                }
            }
        }
    }
    Tensor::from_vec(&[n, cout, bho, bwo, 64], res)
}

/// Inner-loop kernel of the sparse axpy accumulation
/// `y_row += sum_t v_t * Xi[k_t, :]`.
///
/// `Scalar4` / `Scalar8` are the portable unrolled-scalar kernels (the
/// PR-1 and PR-2 tilings, kept so before/after stays a measured
/// ablation).  `Simd` is the explicit `std::arch` path — AVX2+FMA on
/// x86-64 (runtime-detected), NEON on aarch64 — and falls back to
/// `Scalar8` when the running CPU lacks the features or the crate was
/// built with the `no-simd` feature.  `Auto` (the default everywhere)
/// picks `Simd` when available, else `Scalar8`.
///
/// Numerics: the scalar kernels and the band-limited Xi trim are
/// bit-exact with respect to each kernel's own baseline ordering; the
/// SIMD path uses FMA and a different accumulation association, so it
/// is only guaranteed to match within a small reassociation epsilon
/// (see `tests/sparse_equivalence.rs::SIMD_LOGIT_EPSILON`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum AxpyKernel {
    /// 4-wide scalar unroll (one pass over `orow` per 4 nonzeros).
    Scalar4,
    /// 8-wide scalar unroll.
    Scalar8,
    /// `std::arch` vector path (AVX2/FMA or NEON); `Scalar8` fallback.
    Simd,
    /// Runtime pick: `Simd` when available, else `Scalar8`.
    #[default]
    Auto,
}

impl AxpyKernel {
    /// The kernel that will actually run: `Auto` resolves to `Simd`
    /// when the CPU path is available, and a `Simd` request downgrades
    /// to `Scalar8` when it is not.  Never returns `Auto`.
    pub fn effective(self) -> AxpyKernel {
        match self {
            AxpyKernel::Scalar4 => AxpyKernel::Scalar4,
            AxpyKernel::Scalar8 => AxpyKernel::Scalar8,
            AxpyKernel::Simd | AxpyKernel::Auto => {
                if simd_axpy_available() {
                    AxpyKernel::Simd
                } else {
                    AxpyKernel::Scalar8
                }
            }
        }
    }

    /// Stable lowercase name (CLI / config / bench-row spelling).
    pub fn label(self) -> &'static str {
        match self {
            AxpyKernel::Scalar4 => "scalar4",
            AxpyKernel::Scalar8 => "scalar8",
            AxpyKernel::Simd => "simd",
            AxpyKernel::Auto => "auto",
        }
    }
}

impl std::str::FromStr for AxpyKernel {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "scalar4" | "unroll4" => Ok(AxpyKernel::Scalar4),
            "scalar8" | "unroll8" => Ok(AxpyKernel::Scalar8),
            "simd" => Ok(AxpyKernel::Simd),
            "auto" => Ok(AxpyKernel::Auto),
            other => Err(format!(
                "unknown axpy kernel {other:?} (scalar4|scalar8|simd|auto)"
            )),
        }
    }
}

/// Whether the explicit SIMD axpy path can run on this CPU.  x86-64
/// requires AVX2 and FMA (checked at runtime — compile-time `-C
/// target-feature` is not assumed); NEON is baseline on aarch64.
/// Building with `--features no-simd` compiles the vector paths out
/// entirely, which keeps the portable scalar fallback honest in CI.
pub fn simd_axpy_available() -> bool {
    #[cfg(all(target_arch = "x86_64", not(feature = "no-simd")))]
    {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    }
    #[cfg(all(target_arch = "aarch64", not(feature = "no-simd")))]
    {
        true
    }
    #[cfg(not(any(
        all(target_arch = "x86_64", not(feature = "no-simd")),
        all(target_arch = "aarch64", not(feature = "no-simd"))
    )))]
    {
        false
    }
}

/// One 4-wide pass at nonzero offset `t` (consumes exactly nonzeros
/// `t..t+4`); the shared building block of both scalar unrolls.
#[inline]
fn axpy_pass4(orow: &mut [f32], xd: &[f32], xw: usize, base: usize, ks: &[u8], vs: &[f32], t: usize) {
    // `xw` is the row *stride*; the live width is `orow.len()`, which
    // is shorter than the stride inside a column tile
    let n = orow.len();
    let x0 = &xd[(base + ks[t] as usize) * xw..][..n];
    let x1 = &xd[(base + ks[t + 1] as usize) * xw..][..n];
    let x2 = &xd[(base + ks[t + 2] as usize) * xw..][..n];
    let x3 = &xd[(base + ks[t + 3] as usize) * xw..][..n];
    let (v0, v1, v2, v3) = (vs[t], vs[t + 1], vs[t + 2], vs[t + 3]);
    for (o, (((&a0, &a1), &a2), &a3)) in orow
        .iter_mut()
        .zip(x0.iter().zip(x1).zip(x2).zip(x3))
    {
        *o += v0 * a0 + v1 * a1 + v2 * a2 + v3 * a3;
    }
}

/// 4-wide accumulation: one pass over `orow` per 4 nonzeros.
#[inline]
fn axpy_unroll4(orow: &mut [f32], xd: &[f32], xw: usize, base: usize, ks: &[u8], vs: &[f32]) {
    let mut t = 0;
    while t + 4 <= ks.len() {
        axpy_pass4(orow, xd, xw, base, ks, vs, t);
        t += 4;
    }
    axpy_tail(orow, xd, xw, base, ks, vs, t);
}

/// 8-wide accumulation: one pass over `orow` per 8 nonzeros (at quality
/// 50 most blocks store 4-16 nonzeros, so a block is usually one or two
/// passes).  The remainder takes at most one 4-wide pass, then the one
/// shared scalar tail — a single delegation, no re-slicing.
#[inline]
fn axpy_unroll8(orow: &mut [f32], xd: &[f32], xw: usize, base: usize, ks: &[u8], vs: &[f32]) {
    let n = orow.len();
    let mut t = 0;
    while t + 8 <= ks.len() {
        let x0 = &xd[(base + ks[t] as usize) * xw..][..n];
        let x1 = &xd[(base + ks[t + 1] as usize) * xw..][..n];
        let x2 = &xd[(base + ks[t + 2] as usize) * xw..][..n];
        let x3 = &xd[(base + ks[t + 3] as usize) * xw..][..n];
        let x4 = &xd[(base + ks[t + 4] as usize) * xw..][..n];
        let x5 = &xd[(base + ks[t + 5] as usize) * xw..][..n];
        let x6 = &xd[(base + ks[t + 6] as usize) * xw..][..n];
        let x7 = &xd[(base + ks[t + 7] as usize) * xw..][..n];
        let (v0, v1, v2, v3) = (vs[t], vs[t + 1], vs[t + 2], vs[t + 3]);
        let (v4, v5, v6, v7) = (vs[t + 4], vs[t + 5], vs[t + 6], vs[t + 7]);
        for (j, o) in orow.iter_mut().enumerate() {
            *o += v0 * x0[j] + v1 * x1[j] + v2 * x2[j] + v3 * x3[j]
                + v4 * x4[j] + v5 * x5[j] + v6 * x6[j] + v7 * x7[j];
        }
        t += 8;
    }
    if t + 4 <= ks.len() {
        axpy_pass4(orow, xd, xw, base, ks, vs, t);
        t += 4;
    }
    axpy_tail(orow, xd, xw, base, ks, vs, t);
}

/// Scalar tail shared by every kernel: nonzeros `t..` one at a time.
#[inline]
fn axpy_tail(
    orow: &mut [f32],
    xd: &[f32],
    xw: usize,
    base: usize,
    ks: &[u8],
    vs: &[f32],
    mut t: usize,
) {
    while t < ks.len() {
        let v = vs[t];
        let xrow = &xd[(base + ks[t] as usize) * xw..][..orow.len()];
        for (o, &x) in orow.iter_mut().zip(xrow) {
            *o += v * x;
        }
        t += 1;
    }
}

/// Vector axpy front door: dispatches to the per-arch `std::arch`
/// kernel.  Callers must have routed through [`AxpyKernel::effective`],
/// which only selects `Simd` after [`simd_axpy_available`] says yes —
/// that runtime check is what makes the `unsafe` feature-gated calls
/// sound.
#[inline]
fn axpy_simd(orow: &mut [f32], xd: &[f32], xw: usize, base: usize, ks: &[u8], vs: &[f32]) {
    #[cfg(all(target_arch = "x86_64", not(feature = "no-simd")))]
    unsafe {
        axpy_avx2(orow, xd, xw, base, ks, vs)
    }
    #[cfg(all(target_arch = "aarch64", not(feature = "no-simd")))]
    unsafe {
        axpy_neon(orow, xd, xw, base, ks, vs)
    }
    #[cfg(not(any(
        all(target_arch = "x86_64", not(feature = "no-simd")),
        all(target_arch = "aarch64", not(feature = "no-simd"))
    )))]
    axpy_unroll8(orow, xd, xw, base, ks, vs)
}

/// AVX2+FMA axpy: 4 nonzeros per pass, 8 f32 lanes per step, FMA
/// accumulation.  `orow` (the output buffer) and `xd` (the Xi data)
/// are disjoint slices, so the raw-pointer loop bodies never alias;
/// every offset stays inside the bounds-checked row slices taken up
/// front.
#[cfg(all(target_arch = "x86_64", not(feature = "no-simd")))]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn axpy_avx2(orow: &mut [f32], xd: &[f32], xw: usize, base: usize, ks: &[u8], vs: &[f32]) {
    use std::arch::x86_64::*;
    let n = orow.len();
    let op = orow.as_mut_ptr();
    let mut t = 0;
    while t + 4 <= ks.len() {
        let x0 = xd[(base + ks[t] as usize) * xw..][..n].as_ptr();
        let x1 = xd[(base + ks[t + 1] as usize) * xw..][..n].as_ptr();
        let x2 = xd[(base + ks[t + 2] as usize) * xw..][..n].as_ptr();
        let x3 = xd[(base + ks[t + 3] as usize) * xw..][..n].as_ptr();
        let v0 = _mm256_set1_ps(vs[t]);
        let v1 = _mm256_set1_ps(vs[t + 1]);
        let v2 = _mm256_set1_ps(vs[t + 2]);
        let v3 = _mm256_set1_ps(vs[t + 3]);
        let mut j = 0;
        while j + 8 <= n {
            let mut acc = _mm256_loadu_ps(op.add(j));
            acc = _mm256_fmadd_ps(v0, _mm256_loadu_ps(x0.add(j)), acc);
            acc = _mm256_fmadd_ps(v1, _mm256_loadu_ps(x1.add(j)), acc);
            acc = _mm256_fmadd_ps(v2, _mm256_loadu_ps(x2.add(j)), acc);
            acc = _mm256_fmadd_ps(v3, _mm256_loadu_ps(x3.add(j)), acc);
            _mm256_storeu_ps(op.add(j), acc);
            j += 8;
        }
        while j < n {
            *op.add(j) += vs[t] * *x0.add(j)
                + vs[t + 1] * *x1.add(j)
                + vs[t + 2] * *x2.add(j)
                + vs[t + 3] * *x3.add(j);
            j += 1;
        }
        t += 4;
    }
    while t < ks.len() {
        let x = xd[(base + ks[t] as usize) * xw..][..n].as_ptr();
        let v = _mm256_set1_ps(vs[t]);
        let vv = vs[t];
        let mut j = 0;
        while j + 8 <= n {
            let acc = _mm256_fmadd_ps(v, _mm256_loadu_ps(x.add(j)), _mm256_loadu_ps(op.add(j)));
            _mm256_storeu_ps(op.add(j), acc);
            j += 8;
        }
        while j < n {
            *op.add(j) += vv * *x.add(j);
            j += 1;
        }
        t += 1;
    }
}

/// NEON axpy: 4 nonzeros per pass, 4 f32 lanes per step, fused
/// multiply-add via `vfmaq_n_f32`.  Same aliasing argument as the AVX2
/// kernel.
#[cfg(all(target_arch = "aarch64", not(feature = "no-simd")))]
#[target_feature(enable = "neon")]
unsafe fn axpy_neon(orow: &mut [f32], xd: &[f32], xw: usize, base: usize, ks: &[u8], vs: &[f32]) {
    use std::arch::aarch64::*;
    let n = orow.len();
    let op = orow.as_mut_ptr();
    let mut t = 0;
    while t + 4 <= ks.len() {
        let x0 = xd[(base + ks[t] as usize) * xw..][..n].as_ptr();
        let x1 = xd[(base + ks[t + 1] as usize) * xw..][..n].as_ptr();
        let x2 = xd[(base + ks[t + 2] as usize) * xw..][..n].as_ptr();
        let x3 = xd[(base + ks[t + 3] as usize) * xw..][..n].as_ptr();
        let (v0, v1, v2, v3) = (vs[t], vs[t + 1], vs[t + 2], vs[t + 3]);
        let mut j = 0;
        while j + 4 <= n {
            let mut acc = vld1q_f32(op.add(j));
            acc = vfmaq_n_f32(acc, vld1q_f32(x0.add(j)), v0);
            acc = vfmaq_n_f32(acc, vld1q_f32(x1.add(j)), v1);
            acc = vfmaq_n_f32(acc, vld1q_f32(x2.add(j)), v2);
            acc = vfmaq_n_f32(acc, vld1q_f32(x3.add(j)), v3);
            vst1q_f32(op.add(j), acc);
            j += 4;
        }
        while j < n {
            *op.add(j) += v0 * *x0.add(j) + v1 * *x1.add(j) + v2 * *x2.add(j) + v3 * *x3.add(j);
            j += 1;
        }
        t += 4;
    }
    while t < ks.len() {
        let x = xd[(base + ks[t] as usize) * xw..][..n].as_ptr();
        let v = vs[t];
        let mut j = 0;
        while j + 4 <= n {
            let acc = vfmaq_n_f32(vld1q_f32(op.add(j)), vld1q_f32(x.add(j)), v);
            vst1q_f32(op.add(j), acc);
            j += 4;
        }
        while j < n {
            *op.add(j) += v * *x.add(j);
            j += 1;
        }
        t += 1;
    }
}

/// How the sparse conv kernel bounds the live Xi *row* panel.  All
/// three modes are exact (bit-identical outputs): they change which
/// rows are materialized and in what order columns are visited, never
/// the arithmetic any stored coefficient contributes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RowBand {
    /// One panel trimmed to the batch-wide EOB cursor
    /// ([`SparseBlocks::band_cursor`]): a single dense block drags the
    /// whole batch's panel back to full height (the PR-6 behavior).
    Batch,
    /// Two panels: a compact *hot* panel trimmed to a robust quantile
    /// of the per-block cursors ([`SparseBlocks::block_cursors`]) that
    /// most blocks fit under, plus a *tall* fallback panel for the
    /// outliers — so one dense block no longer inflates the working
    /// set every other block streams through.
    PerBlock,
    /// [`RowBand::PerBlock`] plus L1-sized column tiles
    /// ([`XI_TILE_COLS`]): the outer loop walks column tiles, the
    /// inner loop revisits every output row, so a tile of Xi columns
    /// stays cache-hot across the whole row chunk.  The default.
    #[default]
    Tiled,
}

impl RowBand {
    /// Stable ablation-row label (`repro exp axpy`, ci.sh greps these).
    pub fn label(self) -> &'static str {
        match self {
            RowBand::Batch => "batch",
            RowBand::PerBlock => "per-block",
            RowBand::Tiled => "tiled",
        }
    }
}

impl std::str::FromStr for RowBand {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "batch" => Ok(RowBand::Batch),
            "per-block" | "perblock" => Ok(RowBand::PerBlock),
            "tiled" => Ok(RowBand::Tiled),
            other => Err(format!(
                "unknown row band mode {other:?} (batch|per-block|tiled)"
            )),
        }
    }
}

/// Xi column-tile width (f32 elements) of [`RowBand::Tiled`]: 1 KiB
/// per Xi row, so a dozen live zigzag rows plus the matching output
/// row tile sit comfortably in a 32 KiB L1.  Must stay a multiple of
/// 8 (the widest SIMD lane count): tile boundaries then land on the
/// same vector-body/scalar-tail element partition as the untiled
/// pass, which is what keeps tiling bit-identical under FMA.
pub const XI_TILE_COLS: usize = 256;

/// The (possibly band-trimmed) exploded-map panels the kernel reads.
///
/// A full map is `(9*Cin*64, Cout*64)`.  Band limiting shrinks both
/// axes: rows to an EOB cursor bound per `(delta, ci)` 64-row segment,
/// columns to the first `out_cut` zigzag columns of each cout
/// 64-column segment (sound whenever the downstream phi mask discards
/// the rest — `jpeg::zigzag::band_cutoff`).  The row bound is
/// two-tier: blocks whose cursor fits under `hot_cut` read the
/// compact `hot` panel (segment stride `hot_cut`); outlier blocks
/// read the `tall` panel (segment stride 64, columns still trimmed).
/// Both panels are contiguous, so the axpy kernels run on either
/// unchanged, and a mixed-sparsity batch streams the small panel for
/// almost every block.  Under [`RowBand::Batch`], `hot_cut` is the
/// batch-global cursor and `tall` is `None`.
pub struct XiPanels<'a> {
    /// Compact panel: `(9*Cin*hot_cut, Cout*out_cut)`, borrowed
    /// untouched when no trim applies on either axis.
    hot: std::borrow::Cow<'a, Tensor>,
    /// Live zigzag rows per `(delta, ci)` segment of the hot panel.
    hot_cut: usize,
    /// Fallback panel `(9*Cin*64, Cout*out_cut)` for blocks whose
    /// cursor exceeds `hot_cut`; `None` when no block does.
    tall: Option<std::borrow::Cow<'a, Tensor>>,
    /// Live zigzag columns per cout output segment (1..=64).
    out_cut: usize,
}

/// Copy the `(in_cut, out_cut)` band panel out of a full exploded map
/// (borrowed untouched when both cuts are 64 — the full-band path
/// pays nothing).
fn trim_xi<'a>(
    xi: &'a Tensor,
    c: usize,
    cout: usize,
    in_cut: usize,
    out_cut: usize,
) -> std::borrow::Cow<'a, Tensor> {
    if in_cut == 64 && out_cut == 64 {
        return std::borrow::Cow::Borrowed(xi);
    }
    let xd = xi.data();
    let full_w = cout * 64;
    let xw = cout * out_cut;
    let mut trimmed = vec![0.0f32; 9 * c * in_cut * xw];
    for seg in 0..9 * c {
        for k in 0..in_cut {
            let src = &xd[(seg * 64 + k) * full_w..][..full_w];
            let dst = &mut trimmed[(seg * in_cut + k) * xw..][..xw];
            for co in 0..cout {
                dst[co * out_cut..][..out_cut].copy_from_slice(&src[co * 64..][..out_cut]);
            }
        }
    }
    std::borrow::Cow::Owned(Tensor::from_vec(&[9 * c * in_cut, xw], trimmed))
}

/// Smallest cut the bulk of the non-empty blocks fits under: the 7/8
/// quantile of the nonzero cursor histogram.  Robust by construction —
/// up to 1/8 of the non-empty blocks may overflow into the tall panel,
/// so a single dense block cannot inflate `hot_cut`, while uniform
/// batches get `hot_cut == band_cursor()` and degenerate to exactly
/// the batch-global panel (no tall fallback at all).
fn hot_cut_from_histogram(hist: &[u32; 65]) -> usize {
    let nonempty: u64 = hist[1..].iter().map(|&v| v as u64).sum();
    if nonempty == 0 {
        return 1;
    }
    let target = nonempty - nonempty / 8; // ceil(7/8 * nonempty)
    let mut acc = 0u64;
    for (cut, &count) in hist.iter().enumerate().skip(1) {
        acc += count as u64;
        if acc >= target {
            return cut;
        }
    }
    64
}

/// Build the band panels for one conv call: rows bounded per
/// `row_band` by the input's EOB cursors, columns by the downstream
/// phi cutoff.
///
/// Dropping row `(delta*c + ci)*seg + k` with `k >= cut` is exact
/// because a block is only pointed at a panel whose cut its own
/// cursor fits under (see [`sparse_rows_into`]); dropping column
/// `co*64 + k` with `k >= out_cut` is exact *for the caller's
/// pipeline* only when everything downstream provably ignores those
/// coefficients (the executors gate this on their `band_limited`
/// flag — see `plan::SparseKernel`).
fn build_xi_panels<'a>(
    f: &SparseBlocks,
    xi: &'a Tensor,
    cout: usize,
    out_cut: usize,
    row_band: RowBand,
) -> XiPanels<'a> {
    let (_, c, _, _) = f.dims();
    let max_cut = f.band_cursor().max(1);
    let hot_cut = match row_band {
        RowBand::Batch => max_cut,
        RowBand::PerBlock | RowBand::Tiled => {
            hot_cut_from_histogram(&f.cursor_histogram()).clamp(1, max_cut)
        }
    };
    let tall = (hot_cut < max_cut).then(|| trim_xi(xi, c, cout, 64, out_cut));
    XiPanels { hot: trim_xi(xi, c, cout, hot_cut, out_cut), hot_cut, tall, out_cut }
}

/// Gather-free kernel core: compute output rows `[r0, r0 + out.len() /
/// (cout*out_cut))` into `out`, walking only stored nonzeros of each
/// 3x3 block neighborhood.  `out` must be zeroed, row-major `(rows,
/// cout*out_cut)`; `panels` must come from [`build_xi_panels`] on the
/// same input batch.  `kernel` must be resolved
/// ([`AxpyKernel::effective`]).  `occupied`, when given, marks the rows
/// whose input neighborhood stores at least one coefficient — the
/// others are provably zero and skipped outright (see
/// [`occupied_output_rows`]).
///
/// Each contributing block picks its panel from its own EOB cursor —
/// the last stored index the kernel already holds in hand: `hot` when
/// it fits under `hot_cut`, `tall` otherwise.  Panel rows are copies
/// of the same Xi rows, so the switch changes memory layout only.
/// `tile_cols` splits the output row into column tiles (outer loop
/// tiles, inner loop rows): per output element the nonzeros still
/// accumulate in run order, so any tile width that is a multiple of
/// the SIMD lane width is bit-identical to a single full-width pass
/// (pass `xw` for the untiled modes).
fn sparse_rows_into(
    f: &SparseBlocks,
    panels: &XiPanels<'_>,
    cout: usize,
    stride: usize,
    r0: usize,
    out: &mut [f32],
    kernel: AxpyKernel,
    occupied: Option<&[bool]>,
    tile_cols: usize,
) {
    let (_, c, bh, bw) = f.dims();
    let (bho, bwo) = out_blocks(bh, bw, stride);
    let xw = cout * panels.out_cut;
    assert_eq!(
        panels.hot.shape(),
        &[9 * c * panels.hot_cut, xw],
        "hot panel shape mismatch"
    );
    let hot = panels.hot.data();
    let tall = panels.tall.as_deref().map(Tensor::data);
    let nrows = out.len() / xw;
    let mut j0 = 0;
    while j0 < xw {
        let w = tile_cols.min(xw - j0);
        for rloc in 0..nrows {
            let r = r0 + rloc;
            if let Some(occ) = occupied {
                if !occ[r] {
                    continue; // empty 3x3 neighborhood: the row stays zero
                }
            }
            let orow = &mut out[rloc * xw + j0..rloc * xw + j0 + w];
            let b = r / (bho * bwo);
            let rem = r % (bho * bwo);
            let (oy, ox) = (rem / bwo, rem % bwo);
            for delta in 0..9 {
                let Some((iy, ix)) = neighbor(oy, ox, delta, stride, bh, bw) else {
                    continue; // zero-padding block: contributes nothing
                };
                for ci in 0..c {
                    let bid = ((b * c + ci) * bh + iy) * bw + ix;
                    let (ks, vs) = f.block(bid);
                    if ks.is_empty() {
                        continue; // EOB-empty block: skip the base math too
                    }
                    // per-block panel pick: the block's own EOB cursor
                    // is `last + 1`, so `last < hot_cut` iff it fits
                    let last = *ks.last().unwrap() as usize;
                    let (xd, seg) = if last < panels.hot_cut {
                        (hot, panels.hot_cut)
                    } else {
                        (tall.expect("outlier block but no tall panel"), 64)
                    };
                    let xd = &xd[j0..];
                    let base = (delta * c + ci) * seg;
                    match kernel {
                        AxpyKernel::Scalar4 => axpy_unroll4(orow, xd, xw, base, ks, vs),
                        AxpyKernel::Scalar8 => axpy_unroll8(orow, xd, xw, base, ks, vs),
                        AxpyKernel::Simd => axpy_simd(orow, xd, xw, base, ks, vs),
                        AxpyKernel::Auto => unreachable!("Auto resolves before dispatch"),
                    }
                }
            }
        }
        j0 += w;
    }
}

/// Reorder row-major conv output rows straight into [`SparseBlocks`]
/// runs, dropping exact zeros — the sparse-resident twin of
/// [`rows_to_coeff_tensor`] (one scan either way, but no dense
/// `(N, Cout, Bho, Bwo, 64)` intermediate for the next layer to
/// re-scan).  Rows marked unoccupied skip the 64-wide scan and become
/// empty runs directly — bit-identical, since an unoccupied row is
/// provably all-zero and `push_dense_block` over zeros stores nothing.
fn rows_to_sparse_blocks(
    rows: &[f32],
    n: usize,
    cout: usize,
    bho: usize,
    bwo: usize,
    out_cut: usize,
    occupied: Option<&[bool]>,
) -> SparseBlocks {
    let xw = cout * out_cut;
    let mut out = SparseBlocks::with_capacity(n, cout, bho, bwo, rows.len() / 2);
    for b in 0..n {
        for co in 0..cout {
            for oy in 0..bho {
                for ox in 0..bwo {
                    let row = (b * bho + oy) * bwo + ox;
                    if occupied.map_or(false, |occ| !occ[row]) {
                        out.push_block(std::iter::empty());
                        continue;
                    }
                    let src = &rows[row * xw + co * out_cut..][..out_cut];
                    // band-trimmed rows scan only `out_cut` slots: the
                    // coefficients past the cut were never computed and
                    // are exactly zero, so the stored runs are
                    // identical to a 64-wide scan of the full rows
                    out.push_block(
                        src.iter()
                            .enumerate()
                            .filter(|(_, &v)| v != 0.0)
                            .map(|(k, &v)| (k as u8, v)),
                    );
                }
            }
        }
    }
    out
}

/// Per-output-row occupancy cursor for the resident kernel: row `r` is
/// provably all-zero when every block of its 3x3 input neighborhood
/// stores no coefficients.  The per-block CSR pointers (the same
/// cursors behind `SparseBlocks::block_nnz` /
/// `SparseBlocks::block_last_nonzero`) make this an O(1) check per
/// neighbor, so threading the mask through the kernel turns the
/// dense-row accumulation waste on empty regions into an outright
/// skip — of both the axpy accumulation and the 64-wide re-sparsify
/// scan.
fn occupied_output_rows(f: &SparseBlocks, stride: usize) -> Vec<bool> {
    let (n, c, bh, bw) = f.dims();
    let (bho, bwo) = out_blocks(bh, bw, stride);
    let mut occ = vec![false; n * bho * bwo];
    for (r, o) in occ.iter_mut().enumerate() {
        let b = r / (bho * bwo);
        let rem = r % (bho * bwo);
        let (oy, ox) = (rem / bwo, rem % bwo);
        *o = (0..9).any(|delta| match neighbor(oy, ox, delta, stride, bh, bw) {
            Some((iy, ix)) => {
                (0..c).any(|ci| f.block_nnz(((b * c + ci) * bh + iy) * bw + ix) > 0)
            }
            None => false,
        });
    }
    occ
}

/// Apply a materialized exploded map to sparse block input and keep the
/// output sparse — the sparse-resident conv.  Identical kernel core to
/// [`jpeg_conv_exploded_sparse`] (same rows, same threading); only the
/// output materialization differs: nonzeros go straight into runs, so
/// the activation never takes dense `(N, Cout, Bho, Bwo, 64)` form
/// between layers.
pub fn jpeg_conv_exploded_sparse_resident(
    f: &SparseBlocks,
    xi: &Tensor,
    cout: usize,
    stride: usize,
    threads: usize,
) -> SparseBlocks {
    jpeg_conv_exploded_sparse_resident_with(f, xi, cout, stride, threads, AxpyKernel::Auto, 64)
}

/// [`jpeg_conv_exploded_sparse_resident`] with an explicit axpy kernel
/// and output band cutoff (`out_cut = 64` disables column trimming;
/// see [`build_xi_panels`] for when a smaller cutoff is sound).  Runs
/// the default row-band mode ([`RowBand::Tiled`]).
pub fn jpeg_conv_exploded_sparse_resident_with(
    f: &SparseBlocks,
    xi: &Tensor,
    cout: usize,
    stride: usize,
    threads: usize,
    kernel: AxpyKernel,
    out_cut: usize,
) -> SparseBlocks {
    jpeg_conv_exploded_sparse_resident_banded(
        f,
        xi,
        cout,
        stride,
        threads,
        kernel,
        out_cut,
        RowBand::default(),
    )
}

/// [`jpeg_conv_exploded_sparse_resident_with`] with an explicit
/// row-band mode — the full knob set behind `repro exp axpy`.
#[allow(clippy::too_many_arguments)]
pub fn jpeg_conv_exploded_sparse_resident_banded(
    f: &SparseBlocks,
    xi: &Tensor,
    cout: usize,
    stride: usize,
    threads: usize,
    kernel: AxpyKernel,
    out_cut: usize,
    row_band: RowBand,
) -> SparseBlocks {
    let (n, _, bh, bw) = f.dims();
    let (bho, bwo) = out_blocks(bh, bw, stride);
    let occ = occupied_output_rows(f, stride);
    let panels = build_xi_panels(f, xi, cout, out_cut, row_band);
    let rows = compute_sparse_rows(f, &panels, cout, stride, threads, kernel, row_band, Some(&occ));
    rows_to_sparse_blocks(&rows, n, cout, bho, bwo, panels.out_cut, Some(&occ))
}

/// Shared driver of the gather-free kernel: produce the row-major
/// `(N*Bho*Bwo, cout*out_cut)` output rows, inline or threaded.
/// Resolves `Auto`/unavailable-`Simd` once, so every worker runs the
/// same concrete kernel; [`RowBand::Tiled`] sets the column-tile
/// width, the other modes run one full-width tile.
fn compute_sparse_rows(
    f: &SparseBlocks,
    panels: &XiPanels<'_>,
    cout: usize,
    stride: usize,
    threads: usize,
    kernel: AxpyKernel,
    row_band: RowBand,
    occupied: Option<&[bool]>,
) -> Vec<f32> {
    let kernel = kernel.effective();
    let (n, _, bh, bw) = f.dims();
    let (bho, bwo) = out_blocks(bh, bw, stride);
    let rows = n * bho * bwo;
    let xw = cout * panels.out_cut;
    let tile_cols = match row_band {
        RowBand::Tiled => XI_TILE_COLS.min(xw.max(1)),
        RowBand::Batch | RowBand::PerBlock => xw.max(1),
    };
    let mut out = vec![0.0f32; rows * xw];
    let threads = threads.max(1).min(rows.max(1));
    if threads <= 1 {
        sparse_rows_into(f, panels, cout, stride, 0, &mut out, kernel, occupied, tile_cols);
    } else {
        let chunk = rows.div_ceil(threads);
        std::thread::scope(|s| {
            for (i, buf) in out.chunks_mut(chunk * xw).enumerate() {
                s.spawn(move || {
                    sparse_rows_into(
                        f, panels, cout, stride, i * chunk, buf, kernel, occupied, tile_cols,
                    )
                });
            }
        });
    }
    out
}

/// Apply a materialized exploded map to sparse block input — the
/// gather-free kernel, optionally threaded.
///
/// `threads <= 1` runs inline; otherwise output rows are split into
/// contiguous ranges across `threads` scoped workers (each writes a
/// disjoint slice, so results are bit-identical to the single-thread
/// path).  Runs the `Auto` kernel (SIMD when available).
pub fn jpeg_conv_exploded_sparse(
    f: &SparseBlocks,
    xi: &Tensor,
    cout: usize,
    stride: usize,
    threads: usize,
) -> Tensor {
    jpeg_conv_exploded_sparse_with(f, xi, cout, stride, threads, AxpyKernel::Auto, 64)
}

/// [`jpeg_conv_exploded_sparse`] with an explicit axpy kernel and
/// output band cutoff — the knobs behind the `repro exp axpy` ablation.
/// The input-row band is always bounded by EOB cursors (exact; see
/// [`build_xi_panels`]); `out_cut < 64` additionally trims output
/// columns the caller's downstream phi mask will discard.  Runs the
/// default row-band mode ([`RowBand::Tiled`]).
pub fn jpeg_conv_exploded_sparse_with(
    f: &SparseBlocks,
    xi: &Tensor,
    cout: usize,
    stride: usize,
    threads: usize,
    kernel: AxpyKernel,
    out_cut: usize,
) -> Tensor {
    jpeg_conv_exploded_sparse_banded(
        f,
        xi,
        cout,
        stride,
        threads,
        kernel,
        out_cut,
        RowBand::default(),
    )
}

/// [`jpeg_conv_exploded_sparse_with`] with an explicit row-band mode.
#[allow(clippy::too_many_arguments)]
pub fn jpeg_conv_exploded_sparse_banded(
    f: &SparseBlocks,
    xi: &Tensor,
    cout: usize,
    stride: usize,
    threads: usize,
    kernel: AxpyKernel,
    out_cut: usize,
    row_band: RowBand,
) -> Tensor {
    let (n, _, bh, bw) = f.dims();
    let (bho, bwo) = out_blocks(bh, bw, stride);
    let panels = build_xi_panels(f, xi, cout, out_cut, row_band);
    let out = compute_sparse_rows(f, &panels, cout, stride, threads, kernel, row_band, None);
    rows_to_coeff_tensor(&out, n, cout, bho, bwo, panels.out_cut)
}

/// Apply a materialized exploded map — default (sparse, gather-free)
/// path.  Dense input is sparsified first; exact zeros cost nothing
/// downstream.
pub fn jpeg_conv_exploded(f: &Tensor, xi: &Tensor, cout: usize, stride: usize) -> Tensor {
    jpeg_conv_exploded_sparse(&SparseBlocks::from_dense(f), xi, cout, stride, 1)
}

/// Algorithm-1 dense path: gather 3x3 block neighborhoods into a
/// `(N*Bho*Bwo, 9*C*64)` matrix (slice-level copies, no per-element
/// `set`) and multiply by Xi with the cache-tiled dense matmul.  Kept
/// as the measured dense baseline of the sparsity ablation.
pub fn jpeg_conv_exploded_dense(f: &Tensor, xi: &Tensor, cout: usize, stride: usize) -> Tensor {
    let s = f.shape();
    let (n, c, bh, bw) = (s[0], s[1], s[2], s[3]);
    let (bho, bwo) = out_blocks(bh, bw, stride);
    let rows = n * bho * bwo;
    let kwidth = 9 * c * 64;
    let mut a = vec![0.0f32; rows * kwidth];
    for b in 0..n {
        for oy in 0..bho {
            for ox in 0..bwo {
                let row = (b * bho + oy) * bwo + ox;
                let arow = &mut a[row * kwidth..(row + 1) * kwidth];
                for delta in 0..9 {
                    let Some((iy, ix)) = neighbor(oy, ox, delta, stride, bh, bw) else {
                        continue; // zero block (pixel zero padding)
                    };
                    for ci in 0..c {
                        arow[(delta * c + ci) * 64..][..64]
                            .copy_from_slice(f.slice_at(&[b, ci, iy, ix], 64));
                    }
                }
            }
        }
    }
    let out = matmul_tiled(&Tensor::from_vec(&[rows, kwidth], a), xi);
    rows_to_coeff_tensor(out.data(), n, cout, bho, bwo, 64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jpeg_domain::qvec_flat;
    use crate::util::Rng;

    fn rand(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let n: usize = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.normal() * 0.5).collect())
    }

    #[test]
    fn dcc_matches_spatial_conv() {
        let q = qvec_flat();
        let x = rand(&[2, 3, 32, 32], 1);
        let w = rand(&[4, 3, 3, 3], 2);
        let f = encode_tensor(&x, &q);
        let got = decode_tensor(&jpeg_conv_dcc(&f, &w, &q, 1), &q);
        let want = conv2d(&x, &w, 1);
        assert!(got.max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn dcc_stride2_matches() {
        let q = qvec_flat();
        let x = rand(&[1, 2, 32, 32], 3);
        let w = rand(&[2, 2, 3, 3], 4);
        let f = encode_tensor(&x, &q);
        let got = decode_tensor(&jpeg_conv_dcc(&f, &w, &q, 2), &q);
        assert_eq!(got.shape(), &[1, 2, 16, 16]);
        assert!(got.max_abs_diff(&conv2d(&x, &w, 2)) < 1e-3);
    }

    #[test]
    fn exploded_matches_dcc_stride1() {
        let q = qvec_flat();
        let x = rand(&[1, 2, 32, 32], 5);
        let w = rand(&[3, 2, 3, 3], 6);
        let f = encode_tensor(&x, &q);
        let xi = explode_conv(&w, &q, 1);
        let got = jpeg_conv_exploded(&f, &xi, 3, 1);
        let want = jpeg_conv_dcc(&f, &w, &q, 1);
        assert_eq!(got.shape(), want.shape());
        assert!(got.max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn exploded_matches_dcc_stride2() {
        let q = qvec_flat();
        let x = rand(&[1, 2, 16, 16], 7);
        let w = rand(&[2, 2, 3, 3], 8);
        let f = encode_tensor(&x, &q);
        let xi = explode_conv(&w, &q, 2);
        let got = jpeg_conv_exploded(&f, &xi, 2, 2);
        let want = jpeg_conv_dcc(&f, &w, &q, 2);
        assert!(got.max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn exploded_matches_dcc_1x1_stride2() {
        let q = qvec_flat();
        let x = rand(&[1, 2, 16, 16], 9);
        let w = rand(&[4, 2, 1, 1], 10);
        let f = encode_tensor(&x, &q);
        let xi = explode_conv(&w, &q, 2);
        let got = jpeg_conv_exploded(&f, &xi, 4, 2);
        let want = jpeg_conv_dcc(&f, &w, &q, 2);
        assert!(got.max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn exploded_lossy_table() {
        let q = crate::jpeg::QuantTable::luma(80).as_f32();
        let x = rand(&[1, 1, 16, 16], 11);
        let w = rand(&[1, 1, 3, 3], 12);
        let f = encode_tensor(&x, &q);
        let xi = explode_conv(&w, &q, 1);
        let got = jpeg_conv_exploded(&f, &xi, 1, 1);
        let want = jpeg_conv_dcc(&f, &w, &q, 1);
        assert!(got.max_abs_diff(&want) < 1e-2);
    }

    #[test]
    fn dense_path_matches_sparse_path() {
        let q = qvec_flat();
        let x = rand(&[2, 2, 32, 32], 13);
        let w = rand(&[3, 2, 3, 3], 14);
        let f = encode_tensor(&x, &q);
        let xi = explode_conv(&w, &q, 1);
        let sparse = jpeg_conv_exploded(&f, &xi, 3, 1);
        let dense = jpeg_conv_exploded_dense(&f, &xi, 3, 1);
        assert!(dense.max_abs_diff(&sparse) < 1e-3);
    }

    #[test]
    fn threaded_path_is_bit_identical() {
        let q = qvec_flat();
        let x = rand(&[3, 2, 32, 32], 15);
        let w = rand(&[4, 2, 3, 3], 16);
        let f = encode_tensor(&x, &q);
        let xi = explode_conv(&w, &q, 1);
        let fs = SparseBlocks::from_dense(&f);
        let one = jpeg_conv_exploded_sparse(&fs, &xi, 4, 1, 1);
        for threads in [2, 3, 4, 7] {
            let many = jpeg_conv_exploded_sparse(&fs, &xi, 4, 1, threads);
            assert_eq!(one, many, "threads={threads} diverged");
        }
    }

    #[test]
    fn scalar8_matches_scalar4() {
        // tiling only reorders the per-pass accumulation; results must
        // agree to float tolerance on a real lossy-table input
        let q = crate::jpeg::QuantTable::luma(50).as_f32();
        let x = rand(&[2, 2, 32, 32], 18);
        let w = rand(&[3, 2, 3, 3], 19);
        let f = encode_tensor(&x, &q);
        let xi = explode_conv(&w, &q, 1);
        let fs = SparseBlocks::from_dense(&f);
        let u4 = jpeg_conv_exploded_sparse_with(&fs, &xi, 3, 1, 1, AxpyKernel::Scalar4, 64);
        let u8w = jpeg_conv_exploded_sparse_with(&fs, &xi, 3, 1, 1, AxpyKernel::Scalar8, 64);
        assert_eq!(u4.shape(), u8w.shape());
        assert!(u4.max_abs_diff(&u8w) < 1e-4, "{}", u4.max_abs_diff(&u8w));
        // and the default path is the resolved Auto kernel
        let auto = jpeg_conv_exploded_sparse(&fs, &xi, 3, 1, 1);
        let resolved =
            jpeg_conv_exploded_sparse_with(&fs, &xi, 3, 1, 1, AxpyKernel::Auto.effective(), 64);
        assert_eq!(auto, resolved);
    }

    #[test]
    fn kernel_parse_and_resolution() {
        use std::str::FromStr;
        assert_eq!(AxpyKernel::from_str("scalar4").unwrap(), AxpyKernel::Scalar4);
        assert_eq!(AxpyKernel::from_str("unroll8").unwrap(), AxpyKernel::Scalar8);
        assert_eq!(AxpyKernel::from_str("simd").unwrap(), AxpyKernel::Simd);
        assert_eq!(AxpyKernel::from_str("auto").unwrap(), AxpyKernel::Auto);
        assert!(AxpyKernel::from_str("avx512").is_err());
        assert_eq!(AxpyKernel::default(), AxpyKernel::Auto);
        // resolution never yields Auto, and Simd resolves per detection
        for k in [AxpyKernel::Scalar4, AxpyKernel::Scalar8, AxpyKernel::Simd, AxpyKernel::Auto] {
            assert_ne!(k.effective(), AxpyKernel::Auto, "{k:?}");
        }
        let want = if simd_axpy_available() { AxpyKernel::Simd } else { AxpyKernel::Scalar8 };
        assert_eq!(AxpyKernel::Simd.effective(), want);
        assert_eq!(AxpyKernel::Auto.effective(), want);
    }

    /// Naive reference axpy: one nonzero at a time, no unrolling — the
    /// arithmetic every kernel's remainder path must reproduce.
    fn axpy_reference(orow: &mut [f32], xd: &[f32], xw: usize, base: usize, ks: &[u8], vs: &[f32]) {
        axpy_tail(orow, xd, xw, base, ks, vs, 0);
    }

    #[test]
    fn remainder_path_covers_run_lengths_0_to_17() {
        // every kernel, every run length 0..=17: the unroll bodies plus
        // the one shared tail must cover each remainder class (8-wide
        // passes, the single 4-wide pass, and 0..3 scalar tail steps)
        let mut rng = Rng::new(40);
        let xw = 48; // not a multiple of the 8-lane SIMD step
        let xd: Vec<f32> = (0..64 * xw).map(|_| rng.normal()).collect();
        for len in 0..=17usize {
            // `len` ascending zigzag indices drawn from 0..64
            let mut picks: Vec<u8> = (0..64u8).collect();
            for i in 0..picks.len() {
                let j = i + (rng.normal().abs() * 1e4) as usize % (picks.len() - i);
                picks.swap(i, j);
            }
            let mut ks: Vec<u8> = picks[..len].to_vec();
            ks.sort_unstable();
            let vs: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
            let mut want = vec![0.1f32; xw];
            axpy_reference(&mut want, &xd, xw, 0, &ks, &vs);
            for (name, kernel) in [
                ("scalar4", AxpyKernel::Scalar4),
                ("scalar8", AxpyKernel::Scalar8),
                ("simd", AxpyKernel::Simd.effective()),
            ] {
                let mut got = vec![0.1f32; xw];
                match kernel {
                    AxpyKernel::Scalar4 => axpy_unroll4(&mut got, &xd, xw, 0, &ks, &vs),
                    AxpyKernel::Scalar8 => axpy_unroll8(&mut got, &xd, xw, 0, &ks, &vs),
                    AxpyKernel::Simd => axpy_simd(&mut got, &xd, xw, 0, &ks, &vs),
                    AxpyKernel::Auto => unreachable!(),
                }
                let diff = got
                    .iter()
                    .zip(&want)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                assert!(diff < 1e-4, "kernel {name} len {len}: diff {diff}");
            }
        }
    }

    /// Randomized `SparseBlocks` with empty blocks, full 64-coefficient
    /// blocks, and everything between.
    fn random_sparse(n: usize, c: usize, bh: usize, bw: usize, seed: u64) -> SparseBlocks {
        let mut rng = Rng::new(seed);
        let mut s = SparseBlocks::with_capacity(n, c, bh, bw, n * c * bh * bw * 8);
        for bid in 0..n * c * bh * bw {
            let nnz = match bid % 5 {
                0 => 0,                                       // empty block
                1 => 64,                                      // full block
                _ => (rng.normal().abs() * 10.0) as usize % 17, // typical EOB run
            };
            let mut picks: Vec<u8> = (0..64u8).collect();
            for i in 0..picks.len() {
                let j = i + (rng.normal().abs() * 1e4) as usize % (picks.len() - i);
                picks.swap(i, j);
            }
            let mut ks = picks[..nnz].to_vec();
            ks.sort_unstable();
            s.push_block(ks.iter().map(|&k| (k, rng.normal())));
        }
        s
    }

    #[test]
    fn every_kernel_matches_scalar4_on_random_blocks() {
        // property check over randomized inputs, both strides: Scalar4
        // is the reference; Scalar8 and (resolved) Simd must agree to
        // reassociation tolerance, and each kernel must be
        // bit-identical across thread counts
        let q = qvec_flat();
        let w = rand(&[3, 2, 3, 3], 33);
        for (stride, seed) in [(1usize, 50u64), (2, 51)] {
            let xi = explode_conv(&w, &q, stride);
            let fs = random_sparse(2, 2, 4, 4, seed);
            let reference = jpeg_conv_exploded_sparse_with(&fs, &xi, 3, stride, 1, AxpyKernel::Scalar4, 64);
            for kernel in [AxpyKernel::Scalar8, AxpyKernel::Simd.effective()] {
                let got = jpeg_conv_exploded_sparse_with(&fs, &xi, 3, stride, 1, kernel, 64);
                let diff = got.max_abs_diff(&reference);
                assert!(diff < 1e-3, "{kernel:?} stride {stride}: diff {diff}");
                for threads in [2, 5] {
                    let many =
                        jpeg_conv_exploded_sparse_with(&fs, &xi, 3, stride, threads, kernel, 64);
                    assert_eq!(got, many, "{kernel:?} threads {threads} diverged");
                }
            }
        }
    }

    #[test]
    fn row_band_trim_is_bit_identical() {
        // inputs whose EOB cursor sits well below 64: the trimmed-row
        // Xi panel must reproduce the full-panel result bit for bit
        let q = qvec_flat();
        let w = rand(&[3, 2, 3, 3], 34);
        let xi = explode_conv(&w, &q, 1);
        let mut s = SparseBlocks::with_capacity(1, 2, 4, 4, 64);
        let mut rng = Rng::new(60);
        for bid in 0..32 {
            if bid % 3 == 0 {
                s.push_block(std::iter::empty());
            } else {
                // all indices below 11: band_cursor() == 11
                s.push_block((0..=10u8).filter(|k| k % 2 == 0).map(|k| (k, rng.normal())));
            }
        }
        assert_eq!(s.band_cursor(), 11);
        // untrimmed reference: a single full-height panel, no trim
        let full_panels = XiPanels {
            hot: std::borrow::Cow::Borrowed(&xi),
            hot_cut: 64,
            tall: None,
            out_cut: 64,
        };
        let full =
            compute_sparse_rows(&s, &full_panels, 3, 1, 1, AxpyKernel::Scalar8, RowBand::Batch, None);
        let panels = build_xi_panels(&s, &xi, 3, 64, RowBand::Batch);
        assert_eq!(panels.hot_cut, 11, "batch mode trims to the global cursor");
        assert!(panels.tall.is_none(), "no outlier blocks under the global cut");
        let trimmed =
            compute_sparse_rows(&s, &panels, 3, 1, 1, AxpyKernel::Scalar8, RowBand::Batch, None);
        assert_eq!(full, trimmed, "row trim must not change a single bit");
    }

    #[test]
    fn hot_cut_quantile_is_robust_to_outliers() {
        let mut hist = [0u32; 65];
        hist[0] = 100; // empty blocks never vote
        hist[6] = 70; // bulk of the batch is near-empty
        hist[8] = 9;
        hist[64] = 1; // one dense outlier
        assert_eq!(hot_cut_from_histogram(&hist), 8, "7/8 quantile ignores the outlier");
        // uniform batch: quantile == max == batch cursor
        let mut uni = [0u32; 65];
        uni[13] = 42;
        assert_eq!(hot_cut_from_histogram(&uni), 13);
        // all-empty batch falls back to the minimal panel
        assert_eq!(hot_cut_from_histogram(&[0u32; 65]), 1);
    }

    #[test]
    fn per_block_and_tiled_match_batch_bit_for_bit() {
        // mixed-sparsity batch: most blocks store low frequencies only,
        // a few store up to index 63, so per-block mode materializes
        // both panels and routes blocks between them — and every mode
        // must agree with batch-global to the bit, per kernel
        let q = qvec_flat();
        let w = rand(&[3, 2, 3, 3], 40);
        let mut rng = Rng::new(70);
        let mut s = SparseBlocks::with_capacity(2, 2, 4, 4, 256);
        for bid in 0..64 {
            if bid % 7 == 0 {
                s.push_block(std::iter::empty());
            } else if bid % 13 == 0 {
                // dense outlier: full-band run
                s.push_block((0..64u8).map(|k| (k, rng.normal())));
            } else {
                s.push_block((0..=9u8).map(|k| (k, rng.normal())));
            }
        }
        assert_eq!(s.band_cursor(), 64);
        for stride in [1usize, 2] {
            let xi = explode_conv(&w, &q, stride);
            for kernel in [AxpyKernel::Scalar4, AxpyKernel::Scalar8, AxpyKernel::Simd.effective()]
            {
                for out_cut in [64usize, 15] {
                    let batch = jpeg_conv_exploded_sparse_banded(
                        &s, &xi, 3, stride, 1, kernel, out_cut, RowBand::Batch,
                    );
                    for rb in [RowBand::PerBlock, RowBand::Tiled] {
                        let got = jpeg_conv_exploded_sparse_banded(
                            &s, &xi, 3, stride, 1, kernel, out_cut, rb,
                        );
                        assert_eq!(batch, got, "{kernel:?} {rb:?} out_cut {out_cut}");
                        let got4 = jpeg_conv_exploded_sparse_banded(
                            &s, &xi, 3, stride, 4, kernel, out_cut, rb,
                        );
                        assert_eq!(batch, got4, "{kernel:?} {rb:?} threaded");
                    }
                    // resident twin across modes
                    let res_batch = jpeg_conv_exploded_sparse_resident_banded(
                        &s, &xi, 3, stride, 1, kernel, out_cut, RowBand::Batch,
                    );
                    for rb in [RowBand::PerBlock, RowBand::Tiled] {
                        let got = jpeg_conv_exploded_sparse_resident_banded(
                            &s, &xi, 3, stride, 1, kernel, out_cut, rb,
                        );
                        assert_eq!(res_batch, got, "resident {kernel:?} {rb:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn tiling_covers_every_column_at_any_width() {
        // force multiple tiles: cout*out_cut = 3*64 = 192 < XI_TILE_COLS
        // would be one tile, so run the tile loop directly at widths
        // that do and don't divide the row, including SIMD-lane
        // multiples (the bit-identity widths) and a ragged last tile
        let q = qvec_flat();
        let w = rand(&[5, 2, 3, 3], 41);
        let xi = explode_conv(&w, &q, 1);
        let s = random_sparse(1, 2, 4, 4, 71);
        let panels = build_xi_panels(&s, &xi, 5, 64, RowBand::PerBlock);
        let xw = 5 * 64;
        let rows = 16;
        let mut reference = vec![0.0f32; rows * xw];
        sparse_rows_into(&s, &panels, 5, 1, 0, &mut reference, AxpyKernel::Scalar8, None, xw);
        for tile in [8usize, 64, 100, XI_TILE_COLS, xw] {
            let mut out = vec![0.0f32; rows * xw];
            sparse_rows_into(&s, &panels, 5, 1, 0, &mut out, AxpyKernel::Scalar8, None, tile);
            assert_eq!(reference, out, "tile width {tile}");
        }
        // SIMD: bit-identity is guaranteed at lane-multiple widths (the
        // vector-body/scalar-tail partition matches the untiled pass)
        let simd = AxpyKernel::Simd.effective();
        let mut simd_ref = vec![0.0f32; rows * xw];
        sparse_rows_into(&s, &panels, 5, 1, 0, &mut simd_ref, simd, None, xw);
        for tile in [8usize, 64, XI_TILE_COLS] {
            let mut out = vec![0.0f32; rows * xw];
            sparse_rows_into(&s, &panels, 5, 1, 0, &mut out, simd, None, tile);
            assert_eq!(simd_ref, out, "SIMD tile width {tile}");
        }
    }

    #[test]
    fn column_band_trim_zeroes_exactly_the_high_band() {
        // out_cut trims computed columns; the kept prefix must be
        // bit-identical to the full result and the rest exactly zero
        let q = crate::jpeg::QuantTable::luma(50).as_f32();
        let x = rand(&[2, 2, 32, 32], 35);
        let w = rand(&[3, 2, 3, 3], 36);
        let f = encode_tensor(&x, &q);
        let fs = SparseBlocks::from_dense(&f);
        for stride in [1usize, 2] {
            let xi = explode_conv(&w, &q, stride);
            let full = jpeg_conv_exploded_sparse_with(&fs, &xi, 3, stride, 1, AxpyKernel::Scalar8, 64);
            for out_cut in [1usize, 15, 33] {
                let cut = jpeg_conv_exploded_sparse_with(&fs, &xi, 3, stride, 1, AxpyKernel::Scalar8, out_cut);
                assert_eq!(cut.shape(), full.shape());
                for (blk, (cd, fd)) in
                    cut.data().chunks(64).zip(full.data().chunks(64)).enumerate()
                {
                    assert_eq!(&cd[..out_cut], &fd[..out_cut], "block {blk} prefix");
                    assert!(cd[out_cut..].iter().all(|&v| v == 0.0), "block {blk} tail");
                }
            }
            // resident twin: sparsified column-trimmed dense output
            let cut = 15;
            let dense_cut = jpeg_conv_exploded_sparse_with(&fs, &xi, 3, stride, 1, AxpyKernel::Scalar8, cut);
            let resident =
                jpeg_conv_exploded_sparse_resident_with(&fs, &xi, 3, stride, 1, AxpyKernel::Scalar8, cut);
            assert_eq!(resident, SparseBlocks::from_dense(&dense_cut), "stride {stride}");
        }
    }

    #[test]
    fn resident_conv_is_sparsified_dense_output() {
        // resident output == SparseBlocks::from_dense(tensor output),
        // bit for bit, threaded or not
        let q = crate::jpeg::QuantTable::luma(50).as_f32();
        let x = rand(&[2, 2, 32, 32], 21);
        let w = rand(&[3, 2, 3, 3], 22);
        let f = encode_tensor(&x, &q);
        let fs = SparseBlocks::from_dense(&f);
        for stride in [1usize, 2] {
            let xi = explode_conv(&w, &q, stride);
            let dense_out = jpeg_conv_exploded_sparse(&fs, &xi, 3, stride, 1);
            let resident = jpeg_conv_exploded_sparse_resident(&fs, &xi, 3, stride, 1);
            assert_eq!(resident, SparseBlocks::from_dense(&dense_out));
            let threaded = jpeg_conv_exploded_sparse_resident(&fs, &xi, 3, stride, 4);
            assert_eq!(resident, threaded);
        }
    }

    #[test]
    fn resident_conv_skips_empty_neighborhoods_bit_identically() {
        // image 2 of the batch is all zeros: every one of its output
        // rows has an empty 3x3 neighborhood, so the occupancy cursor
        // skips both the accumulation and the re-sparsify scan — and
        // the result must still equal the dense path's sparsified
        // output, with empty runs for the zero image
        let q = crate::jpeg::QuantTable::luma(50).as_f32();
        let x = rand(&[2, 2, 32, 32], 25);
        let mut d = x.data().to_vec();
        for v in &mut d[2 * 32 * 32..] {
            *v = 0.0; // zero both channels of image 2
        }
        let x = Tensor::from_vec(&[2, 2, 32, 32], d);
        let w = rand(&[3, 2, 3, 3], 26);
        let f = encode_tensor(&x, &q);
        let fs = SparseBlocks::from_dense(&f);
        for stride in [1usize, 2] {
            let xi = explode_conv(&w, &q, stride);
            let dense_out = jpeg_conv_exploded_sparse(&fs, &xi, 3, stride, 1);
            let resident = jpeg_conv_exploded_sparse_resident(&fs, &xi, 3, stride, 1);
            assert_eq!(resident, SparseBlocks::from_dense(&dense_out), "stride {stride}");
            // image 2's blocks are all empty runs
            let (_, _, bho, bwo) = resident.dims();
            let per_image = 3 * bho * bwo;
            for bid in per_image..2 * per_image {
                assert_eq!(resident.block_nnz(bid), 0, "bid {bid}");
            }
            // threaded path agrees with the mask applied per chunk
            assert_eq!(resident, jpeg_conv_exploded_sparse_resident(&fs, &xi, 3, stride, 4));
        }
    }

    #[test]
    fn sparse_input_skips_padding_blocks() {
        // an all-zero input must produce an all-zero output through the
        // sparse path (no gather matrix, no border contributions)
        let q = qvec_flat();
        let w = rand(&[2, 1, 3, 3], 17);
        let xi = explode_conv(&w, &q, 1);
        let f = SparseBlocks::from_dense(&Tensor::zeros(&[1, 1, 4, 4, 64]));
        assert_eq!(f.nnz(), 0);
        let y = jpeg_conv_exploded_sparse(&f, &xi, 2, 1, 1);
        assert_eq!(y.shape(), &[1, 2, 4, 4, 64]);
        assert!(y.data().iter().all(|&v| v == 0.0));
    }
}
