//! Procedural generators for the three dataset substitutes.

use crate::jpeg::PixelImage;
use crate::util::Rng;

use super::Example;

/// Which synthetic distribution to draw from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SynthKind {
    Mnist,
    Cifar10,
    Cifar100,
}

impl SynthKind {
    pub fn parse(name: &str) -> Option<Self> {
        match name {
            "mnist" => Some(SynthKind::Mnist),
            "cifar10" => Some(SynthKind::Cifar10),
            "cifar100" => Some(SynthKind::Cifar100),
            _ => None,
        }
    }

    pub fn channels(&self) -> usize {
        match self {
            SynthKind::Mnist => 1,
            _ => 3,
        }
    }

    pub fn num_classes(&self) -> usize {
        match self {
            SynthKind::Mnist | SynthKind::Cifar10 => 10,
            SynthKind::Cifar100 => 100,
        }
    }
}

const SIZE: usize = 32;

/// Digit-like stroke templates: each class is a sequence of line segments
/// in a normalized [0,1]^2 box (loosely the seven-segment shapes).
fn glyph_strokes(class: u32) -> &'static [((f32, f32), (f32, f32))] {
    // segments: a=top, b=tr, c=br, d=bottom, e=bl, f=tl, g=middle
    const A: ((f32, f32), (f32, f32)) = ((0.2, 0.15), (0.8, 0.15));
    const B: ((f32, f32), (f32, f32)) = ((0.8, 0.15), (0.8, 0.5));
    const C: ((f32, f32), (f32, f32)) = ((0.8, 0.5), (0.8, 0.85));
    const D: ((f32, f32), (f32, f32)) = ((0.2, 0.85), (0.8, 0.85));
    const E: ((f32, f32), (f32, f32)) = ((0.2, 0.5), (0.2, 0.85));
    const F: ((f32, f32), (f32, f32)) = ((0.2, 0.15), (0.2, 0.5));
    const G: ((f32, f32), (f32, f32)) = ((0.2, 0.5), (0.8, 0.5));
    match class {
        0 => &[A, B, C, D, E, F],
        1 => &[B, C],
        2 => &[A, B, G, E, D],
        3 => &[A, B, G, C, D],
        4 => &[F, G, B, C],
        5 => &[A, F, G, C, D],
        6 => &[A, F, E, D, C, G],
        7 => &[A, B, C],
        8 => &[A, B, C, D, E, F, G],
        _ => &[A, B, C, D, F, G],
    }
}

/// Distance from point to segment (for stroke rasterization).
fn seg_dist(px: f32, py: f32, a: (f32, f32), b: (f32, f32)) -> f32 {
    let (ax, ay) = a;
    let (bx, by) = b;
    let (dx, dy) = (bx - ax, by - ay);
    let len2 = dx * dx + dy * dy;
    let t = if len2 > 0.0 {
        (((px - ax) * dx + (py - ay) * dy) / len2).clamp(0.0, 1.0)
    } else {
        0.0
    };
    let (cx, cy) = (ax + t * dx, ay + t * dy);
    ((px - cx).powi(2) + (py - cy).powi(2)).sqrt()
}

/// One MNIST-like glyph with affine jitter and noise.
fn mnist_example(class: u32, rng: &mut Rng) -> PixelImage {
    let mut img = PixelImage::new(1, SIZE, SIZE);
    let strokes = glyph_strokes(class);
    // affine jitter
    let angle = rng.uniform_in(-0.25, 0.25);
    let scale = rng.uniform_in(0.85, 1.15);
    let (tx, ty) = (rng.uniform_in(-0.08, 0.08), rng.uniform_in(-0.08, 0.08));
    let thick = rng.uniform_in(0.045, 0.08);
    let (sin, cos) = angle.sin_cos();
    for y in 0..SIZE {
        for x in 0..SIZE {
            // map pixel to glyph space (inverse affine about the center)
            let u = x as f32 / SIZE as f32 - 0.5 - tx;
            let v = y as f32 / SIZE as f32 - 0.5 - ty;
            let gu = (cos * u + sin * v) / scale + 0.5;
            let gv = (-sin * u + cos * v) / scale + 0.5;
            let d = strokes
                .iter()
                .map(|&(a, b)| seg_dist(gu, gv, a, b))
                .fold(f32::INFINITY, f32::min);
            // soft stroke profile + background noise
            let ink = (1.0 - (d / thick).powi(2)).max(0.0);
            let val = 255.0 * ink + rng.uniform_in(0.0, 18.0);
            img.set(0, y, x, val.clamp(0.0, 255.0));
        }
    }
    img
}

/// Class-conditioned texture parameters for CIFAR-like data.
struct TextureParams {
    freq: f32,
    angle: f32,
    palette: [(f32, f32, f32); 2],
    blob_cx: f32,
    blob_cy: f32,
    blob_amp: f32,
}

fn texture_params(kind: SynthKind, class: u32) -> TextureParams {
    // deterministic per-class parameters from a hash of the class id
    let mut h = 0x9E37_79B9_7F4A_7C15u64 ^ ((class as u64) << 7) ^ kind as u64;
    let mut next = || crate::util::splitmix64(&mut h) as f64 / u64::MAX as f64;
    let freq = 1.5 + 6.0 * next() as f32;
    let angle = std::f64::consts::PI as f32 * next() as f32;
    let c0 = (
        60.0 + 180.0 * next() as f32,
        60.0 + 180.0 * next() as f32,
        60.0 + 180.0 * next() as f32,
    );
    let c1 = (
        40.0 + 180.0 * next() as f32,
        40.0 + 180.0 * next() as f32,
        40.0 + 180.0 * next() as f32,
    );
    TextureParams {
        freq,
        angle,
        palette: [c0, c1],
        blob_cx: 0.25 + 0.5 * next() as f32,
        blob_cy: 0.25 + 0.5 * next() as f32,
        blob_amp: 30.0 + 50.0 * next() as f32,
    }
}

/// One CIFAR-like textured example with photometric jitter.
fn cifar_example(kind: SynthKind, class: u32, rng: &mut Rng) -> PixelImage {
    let p = texture_params(kind, class);
    let mut img = PixelImage::new(3, SIZE, SIZE);
    let phase = rng.uniform_in(0.0, std::f32::consts::TAU);
    let gain = rng.uniform_in(0.8, 1.2);
    let angle = p.angle + rng.uniform_in(-0.12, 0.12);
    let (sin, cos) = angle.sin_cos();
    for y in 0..SIZE {
        for x in 0..SIZE {
            let u = x as f32 / SIZE as f32;
            let v = y as f32 / SIZE as f32;
            // oriented grating in [0,1]
            let t = 0.5 + 0.5 * (p.freq * std::f32::consts::TAU * (cos * u + sin * v) + phase).sin();
            // radial blob bump
            let db = ((u - p.blob_cx).powi(2) + (v - p.blob_cy).powi(2)).sqrt();
            let blob = p.blob_amp * (-14.0 * db * db).exp();
            let (c0, c1) = (p.palette[0], p.palette[1]);
            let mix = |a: f32, b: f32| (a * t + b * (1.0 - t)) * gain;
            let noise = rng.uniform_in(-7.0, 7.0);
            img.set(0, y, x, (mix(c0.0, c1.0) + blob + noise).clamp(0.0, 255.0));
            img.set(1, y, x, (mix(c0.1, c1.1) + blob + noise).clamp(0.0, 255.0));
            img.set(2, y, x, (mix(c0.2, c1.2) - blob + noise).clamp(0.0, 255.0));
        }
    }
    img
}

/// Generate `n` labeled examples, deterministic in (kind, seed).
pub fn generate(kind: SynthKind, n: usize, seed: u64) -> Vec<Example> {
    let mut rng = Rng::new(seed ^ 0xDA7A_5E7);
    (0..n)
        .map(|i| {
            let label = (i % kind.num_classes()) as u32;
            let pixels = match kind {
                SynthKind::Mnist => mnist_example(label, &mut rng),
                k => cifar_example(k, label, &mut rng),
            };
            Example { pixels, label }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = generate(SynthKind::Mnist, 8, 1);
        let b = generate(SynthKind::Mnist, 8, 1);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.label, y.label);
            assert_eq!(x.pixels.data, y.pixels.data);
        }
    }

    #[test]
    fn seeds_differ() {
        let a = generate(SynthKind::Mnist, 4, 1);
        let b = generate(SynthKind::Mnist, 4, 2);
        assert_ne!(a[0].pixels.data, b[0].pixels.data);
    }

    #[test]
    fn labels_cycle_all_classes() {
        let ex = generate(SynthKind::Cifar100, 200, 3);
        let mut seen = vec![false; 100];
        for e in &ex {
            seen[e.label as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shapes_and_range() {
        for kind in [SynthKind::Mnist, SynthKind::Cifar10, SynthKind::Cifar100] {
            let ex = generate(kind, 3, 4);
            for e in &ex {
                assert_eq!(e.pixels.channels, kind.channels());
                assert_eq!((e.pixels.height, e.pixels.width), (32, 32));
                assert!(e
                    .pixels
                    .data
                    .iter()
                    .all(|&v| (0.0..=255.0).contains(&v)));
            }
        }
    }

    #[test]
    fn classes_are_separated() {
        // same-class images are closer than cross-class ones on average
        let ex = generate(SynthKind::Cifar10, 60, 5);
        let dist = |a: &Example, b: &Example| -> f32 {
            a.pixels
                .data
                .iter()
                .zip(&b.pixels.data)
                .map(|(x, y)| (x - y) * (x - y))
                .sum::<f32>()
                / a.pixels.data.len() as f32
        };
        let mut same = Vec::new();
        let mut diff = Vec::new();
        for i in 0..ex.len() {
            for j in i + 1..ex.len() {
                if ex[i].label == ex[j].label {
                    same.push(dist(&ex[i], &ex[j]));
                } else {
                    diff.push(dist(&ex[i], &ex[j]));
                }
            }
        }
        let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len() as f32;
        assert!(mean(&same) < mean(&diff), "{} vs {}", mean(&same), mean(&diff));
    }

    #[test]
    fn glyphs_have_ink() {
        let ex = generate(SynthKind::Mnist, 10, 6);
        for e in &ex {
            let bright = e.pixels.data.iter().filter(|&&v| v > 128.0).count();
            assert!(bright > 20, "class {} has {} bright px", e.label, bright);
        }
    }
}
