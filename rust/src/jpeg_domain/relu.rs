//! ASM and APX ReLU over domain coefficient blocks (paper §4.2).
//!
//! This is the rust mirror of the L1 Pallas `asm_relu` kernel: the
//! 3-matmul factored form of the harmonic mixing tensor.  The Fig-4a
//! harness pushes millions of blocks through these, so the inner loops
//! are written over flat slices with hoisted row pointers.
//!
//! Two activation representations are supported: [`jpeg_relu`] over a
//! dense coefficient tensor, and [`jpeg_relu_sparse`] over
//! [`SparseBlocks`] runs for the sparse-resident network path.  The
//! sparse form performs the *same* float operations on the same
//! nonzeros in the same order (the dense kernel already skips zero
//! terms), so the two are bit-identical; all-zero blocks short-circuit
//! to empty output runs, and the phi band mask is applied as a run
//! truncation ([`crate::jpeg::zigzag::band_cutoff`]) instead of a
//! 64-wide multiply.

use crate::jpeg::zigzag::{band_cutoff, band_mask};
use crate::tensor::{SparseBlocks, Tensor};

use super::{dec_matrix, enc_matrix};

/// Precomputed matrices for a quantization vector.
pub struct ReluCtx {
    /// (64,64) coeff -> spatial (includes dequantization)
    pub dec: Tensor,
    /// (64,64) spatial -> coeff (includes quantization)
    pub enc: Tensor,
}

impl ReluCtx {
    pub fn new(qvec: &[f32; 64]) -> Self {
        ReluCtx { dec: dec_matrix(qvec), enc: enc_matrix(qvec) }
    }
}

#[inline]
fn matvec64(m: &[f32], f: &[f32], out: &mut [f32; 64]) {
    // out[p] = sum_k f[k] * m[k*64+p]   (row-vector x matrix)
    out.fill(0.0);
    for (k, &v) in f.iter().enumerate() {
        if v == 0.0 {
            continue;
        }
        let row = &m[k * 64..(k + 1) * 64];
        for (o, &a) in out.iter_mut().zip(row) {
            *o += v * a;
        }
    }
}

/// ASM ReLU on one zigzag block: exact values gated by the truncated-
/// frequency nonnegative mask (paper Algorithm 2, factored form).
pub fn asm_relu_block(ctx: &ReluCtx, f: &[f32; 64], mask: &[f32; 64]) -> [f32; 64] {
    let dec = ctx.dec.data();
    let enc = ctx.enc.data();
    let mut x_exact = [0.0f32; 64];
    matvec64(dec, f, &mut x_exact);
    let mut fm = [0.0f32; 64];
    for k in 0..64 {
        fm[k] = f[k] * mask[k];
    }
    let mut x_apx = [0.0f32; 64];
    matvec64(dec, &fm, &mut x_apx);
    let mut gated = [0.0f32; 64];
    for p in 0..64 {
        gated[p] = if x_apx[p] > 0.0 { x_exact[p] } else { 0.0 };
    }
    let mut out = [0.0f32; 64];
    matvec64(enc, &gated, &mut out);
    out
}

/// APX ReLU: ReLU applied directly to the truncated reconstruction.
pub fn apx_relu_block(ctx: &ReluCtx, f: &[f32; 64], mask: &[f32; 64]) -> [f32; 64] {
    let dec = ctx.dec.data();
    let enc = ctx.enc.data();
    let mut fm = [0.0f32; 64];
    for k in 0..64 {
        fm[k] = f[k] * mask[k];
    }
    let mut x_apx = [0.0f32; 64];
    matvec64(dec, &fm, &mut x_apx);
    for v in &mut x_apx {
        *v = v.max(0.0);
    }
    let mut out = [0.0f32; 64];
    matvec64(enc, &x_apx, &mut out);
    out
}

/// Sparse-run matvec: `out[p] = sum_t val[t] * m[idx[t]*64+p]`.
///
/// Walks only the stored nonzeros of a run.  [`matvec64`] skips zero
/// entries of its dense input, so for the run of a block's nonzeros
/// this performs the identical adds in the identical (ascending
/// zigzag) order — results are bit-for-bit equal.
#[inline]
fn matvec_run(m: &[f32], idx: &[u8], val: &[f32], out: &mut [f32; 64]) {
    out.fill(0.0);
    for (&k, &v) in idx.iter().zip(val) {
        let row = &m[k as usize * 64..(k as usize + 1) * 64];
        for (o, &a) in out.iter_mut().zip(row) {
            *o += v * a;
        }
    }
}

/// ASM ReLU on one sparse run: the phi mask is a run truncation at
/// `cutoff` (the mask's zigzag prefix length).  Output is the dense
/// 64-vector of coefficients; the caller keeps its nonzeros.
pub fn asm_relu_run(ctx: &ReluCtx, idx: &[u8], val: &[f32], cutoff: usize) -> [f32; 64] {
    let dec = ctx.dec.data();
    let enc = ctx.enc.data();
    let mut x_exact = [0.0f32; 64];
    matvec_run(dec, idx, val, &mut x_exact);
    // phi mask == keep the run prefix below the band cutoff
    let t = idx.partition_point(|&k| (k as usize) < cutoff);
    let mut x_apx = [0.0f32; 64];
    matvec_run(dec, &idx[..t], &val[..t], &mut x_apx);
    let mut gated = [0.0f32; 64];
    for p in 0..64 {
        gated[p] = if x_apx[p] > 0.0 { x_exact[p] } else { 0.0 };
    }
    let mut out = [0.0f32; 64];
    matvec64(enc, &gated, &mut out);
    out
}

/// APX ReLU on one sparse run (mask = run truncation, as in
/// [`asm_relu_run`]).
pub fn apx_relu_run(ctx: &ReluCtx, idx: &[u8], val: &[f32], cutoff: usize) -> [f32; 64] {
    let dec = ctx.dec.data();
    let enc = ctx.enc.data();
    let t = idx.partition_point(|&k| (k as usize) < cutoff);
    let mut x_apx = [0.0f32; 64];
    matvec_run(dec, &idx[..t], &val[..t], &mut x_apx);
    for v in &mut x_apx {
        *v = v.max(0.0);
    }
    let mut out = [0.0f32; 64];
    matvec64(enc, &x_apx, &mut out);
    out
}

/// Apply ASM/APX ReLU over sparse block runs, producing sparse runs —
/// the sparse-resident form of [`jpeg_relu`].  All-zero blocks are
/// skipped outright (both methods map the zero block to the zero
/// block); output blocks store exactly the nonzero coefficients the
/// dense kernel would produce, so a subsequent sparse consumer sees
/// bit-identical inputs either way.
pub fn jpeg_relu_sparse(
    f: &SparseBlocks,
    qvec: &[f32; 64],
    num_freqs: usize,
    method: Method,
) -> SparseBlocks {
    let ctx = ReluCtx::new(qvec);
    let cutoff = band_cutoff(num_freqs);
    let (n, c, bh, bw) = f.dims();
    let mut out = SparseBlocks::with_capacity(n, c, bh, bw, f.nnz());
    for bid in 0..f.num_blocks() {
        let (idx, val) = f.block(bid);
        if idx.is_empty() {
            out.push_block(std::iter::empty());
            continue;
        }
        let r = match method {
            Method::Asm => asm_relu_run(&ctx, idx, val, cutoff),
            Method::Apx => apx_relu_run(&ctx, idx, val, cutoff),
        };
        out.push_dense_block(&r);
    }
    out
}

/// Apply ASM/APX ReLU over a whole coefficient tensor (..., 64).
pub fn jpeg_relu(f: &Tensor, qvec: &[f32; 64], num_freqs: usize, method: Method) -> Tensor {
    let ctx = ReluCtx::new(qvec);
    let mask = band_mask(num_freqs);
    let mut out = vec![0.0f32; f.len()];
    let mut blk = [0.0f32; 64];
    for (i, chunk) in f.data().chunks_exact(64).enumerate() {
        blk.copy_from_slice(chunk);
        let r = match method {
            Method::Asm => asm_relu_block(&ctx, &blk, &mask),
            Method::Apx => apx_relu_block(&ctx, &blk, &mask),
        };
        out[i * 64..(i + 1) * 64].copy_from_slice(&r);
    }
    Tensor::from_vec(f.shape(), out)
}

/// ReLU approximation method (the paper's comparison axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Asm,
    Apx,
}

impl std::str::FromStr for Method {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "asm" => Ok(Method::Asm),
            "apx" => Ok(Method::Apx),
            other => Err(format!("unknown relu method {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jpeg_domain::{decode_tensor, encode_tensor, qvec_flat};
    use crate::util::Rng;

    fn rand_blocks(seed: u64, m: usize) -> Tensor {
        let mut rng = Rng::new(seed);
        Tensor::from_vec(
            &[m, 64],
            (0..m * 64).map(|_| rng.normal()).collect(),
        )
    }

    #[test]
    fn exact_at_15_freqs() {
        let q = qvec_flat();
        let mut rng = Rng::new(1);
        let x = Tensor::from_vec(
            &[1, 1, 16, 16],
            (0..256).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
        );
        let f = encode_tensor(&x, &q);
        let r = jpeg_relu(&f, &q, 15, Method::Asm);
        let back = decode_tensor(&r, &q);
        assert!(back.max_abs_diff(&x.relu()) < 1e-4);
    }

    #[test]
    fn asm_preserves_or_zeroes_pixels() {
        // paper Figure 1: ASM output pixels are exact or exactly zero
        let q = qvec_flat();
        let ctx = ReluCtx::new(&q);
        let mask = band_mask(6);
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let mut x = [0.0f32; 64];
            for v in &mut x {
                *v = rng.normal();
            }
            // encode block
            let xt = Tensor::from_vec(&[1, 1, 8, 8], x.to_vec());
            let f = encode_tensor(&xt, &q);
            let mut fb = [0.0f32; 64];
            fb.copy_from_slice(f.data());
            let out = asm_relu_block(&ctx, &fb, &mask);
            let ot = Tensor::from_vec(&[1, 1, 1, 1, 64], out.to_vec());
            let xo = decode_tensor(&ot, &q);
            for (a, &b) in xo.data().iter().zip(&x) {
                let kept = (a - b).abs() < 1e-4;
                let zeroed = a.abs() < 1e-4;
                assert!(kept || zeroed, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn asm_beats_apx_rmse() {
        // the Fig-4a ordering
        let q = qvec_flat();
        let ctx = ReluCtx::new(&q);
        let mut rng = Rng::new(3);
        for nf in [4usize, 8, 12] {
            let mask = band_mask(nf);
            let (mut se_asm, mut se_apx) = (0.0f64, 0.0f64);
            let n = 500;
            for _ in 0..n {
                let mut x = [0.0f32; 64];
                for v in &mut x {
                    *v = rng.uniform_in(-1.0, 1.0);
                }
                let xt = Tensor::from_vec(&[1, 1, 8, 8], x.to_vec());
                let f = encode_tensor(&xt, &q);
                let mut fb = [0.0f32; 64];
                fb.copy_from_slice(f.data());
                let results = [
                    asm_relu_block(&ctx, &fb, &mask),
                    apx_relu_block(&ctx, &fb, &mask),
                ];
                for (out, se) in results.iter().zip([&mut se_asm, &mut se_apx]) {
                    let ot = Tensor::from_vec(&[1, 1, 1, 1, 64], out.to_vec());
                    let xo = decode_tensor(&ot, &q);
                    for (a, &b) in xo.data().iter().zip(&x) {
                        let d = (a - b.max(0.0)) as f64;
                        *se += d * d;
                    }
                }
            }
            assert!(se_asm < se_apx, "nf={nf}: {se_asm} vs {se_apx}");
        }
    }

    #[test]
    fn whole_tensor_matches_blockwise() {
        let q = qvec_flat();
        let f = rand_blocks(4, 10).reshape(&[1, 1, 2, 5, 64]);
        let out = jpeg_relu(&f, &q, 8, Method::Asm);
        let ctx = ReluCtx::new(&q);
        let mask = band_mask(8);
        for (i, chunk) in f.data().chunks_exact(64).enumerate() {
            let mut fb = [0.0f32; 64];
            fb.copy_from_slice(chunk);
            let expect = asm_relu_block(&ctx, &fb, &mask);
            for k in 0..64 {
                assert!((out.data()[i * 64 + k] - expect[k]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn sparse_relu_bit_identical_to_dense() {
        use crate::tensor::SparseBlocks;
        let q = crate::jpeg::QuantTable::luma(50).as_f32();
        let mut rng = Rng::new(9);
        // sparse-ish random coefficient batch with empty blocks too
        let mut data = vec![0.0f32; 2 * 2 * 2 * 2 * 64];
        for v in data.iter_mut() {
            if rng.uniform() < 0.25 {
                *v = rng.normal();
            }
        }
        for blk in 0..4 {
            // force a few all-zero blocks (the short-circuit path)
            for k in 0..64 {
                data[blk * 5 * 64 + k] = 0.0;
            }
        }
        let f = Tensor::from_vec(&[2, 2, 2, 2, 64], data);
        let fs = SparseBlocks::from_dense(&f);
        for nf in [4usize, 8, 15] {
            for method in [Method::Asm, Method::Apx] {
                let dense = jpeg_relu(&f, &q, nf, method);
                let sparse = jpeg_relu_sparse(&fs, &q, nf, method);
                assert_eq!(
                    sparse,
                    SparseBlocks::from_dense(&dense),
                    "nf={nf} method={method:?}"
                );
            }
        }
    }

    #[test]
    fn method_parse() {
        assert_eq!("asm".parse::<Method>().unwrap(), Method::Asm);
        assert_eq!("apx".parse::<Method>().unwrap(), Method::Apx);
        assert!("bad".parse::<Method>().is_err());
    }
}
