"""L1 Pallas kernels vs the pure-jnp oracles in ref.py.

Hypothesis sweeps shapes/seeds; every kernel must match its oracle to
float tolerance, including through the custom VJPs.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import jpeg_ops as jo
from compile.kernels import (
    asm_relu_blocks, apx_relu_blocks, block_matmul, block_transform, ref)

Q_FLAT = jo.QTABLE_FLAT
Q_75 = jo.quality_scale(jo.ANNEX_K_LUMA, 75)


def mats(q):
    return jnp.asarray(jo.dec_matrix(q)), jnp.asarray(jo.enc_matrix(q))


def rand_blocks(seed, m):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(m, 64)).astype(np.float32))


# ---------------------------------------------------------------------------
# block_transform
# ---------------------------------------------------------------------------
class TestBlockTransform:
    @pytest.mark.parametrize("m", [1, 7, 256, 300, 1000])
    def test_matches_ref(self, m):
        x = rand_blocks(0, m)
        t = jnp.asarray(jo.ZA.T.astype(np.float32))
        np.testing.assert_allclose(
            block_transform(x, t), ref.block_transform(x, t), atol=1e-5)

    def test_grad_matches_ref(self):
        x = rand_blocks(1, 100)
        t = jnp.asarray(jo.ZA.T.astype(np.float32))
        g1 = jax.grad(lambda a: jnp.sum(block_transform(a, t) ** 2))(x)
        g2 = jax.grad(lambda a: jnp.sum(ref.block_transform(a, t) ** 2))(x)
        np.testing.assert_allclose(g1, g2, atol=1e-4)

    def test_weight_grad(self):
        x = rand_blocks(2, 64)
        t = jnp.asarray(jo.ZA.T.astype(np.float32))
        g1 = jax.grad(lambda w: jnp.sum(block_transform(x, w) ** 2))(t)
        g2 = jax.grad(lambda w: jnp.sum(ref.block_transform(x, w) ** 2))(t)
        np.testing.assert_allclose(g1, g2, atol=1e-4)


# ---------------------------------------------------------------------------
# ASM / APX ReLU
# ---------------------------------------------------------------------------
class TestAsmRelu:
    @pytest.mark.parametrize("nf", [1, 3, 6, 10, 15])
    @pytest.mark.parametrize("qname", ["flat", "q75"])
    def test_matches_ref(self, nf, qname):
        q = Q_FLAT if qname == "flat" else Q_75
        dec, enc = mats(q)
        f = rand_blocks(nf, 300)
        mask = jnp.asarray(jo.band_mask(nf))
        np.testing.assert_allclose(
            asm_relu_blocks(f, mask, dec, enc),
            ref.asm_relu_blocks(f, mask, dec, enc), atol=1e-4)

    def test_exact_at_15(self):
        """phi=15 must be the exact ReLU (paper §5.2 sanity check)."""
        rng = np.random.default_rng(3)
        q = jnp.asarray(Q_75)
        x = jnp.asarray(rng.normal(size=(2, 1, 16, 16)).astype(np.float32))
        c = jo.encode(x, q)
        dec, enc = mats(Q_75)
        out = asm_relu_blocks(
            c.reshape(-1, 64), jnp.asarray(jo.band_mask(15)), dec, enc)
        xr = jo.decode(out.reshape(c.shape), q)
        np.testing.assert_allclose(xr, jnp.maximum(x, 0), atol=1e-4)

    def test_asm_preserves_positive_values(self):
        """Paper Figure 1: ASM never alters the value of a kept pixel —
        output pixels are either the exact input or exactly zero."""
        rng = np.random.default_rng(4)
        dec, enc = mats(Q_FLAT)
        x = jnp.asarray(rng.normal(size=(50, 64)).astype(np.float32))
        f = x @ enc
        out = asm_relu_blocks(f, jnp.asarray(jo.band_mask(6)), dec, enc)
        xo = np.array(out @ dec)
        xi = np.array(x)
        is_kept = np.abs(xo - xi) < 1e-4
        is_zero = np.abs(xo) < 1e-4
        assert np.all(is_kept | is_zero)

    def test_apx_matches_ref(self):
        dec, enc = mats(Q_FLAT)
        f = rand_blocks(5, 200)
        for nf in (2, 8, 15):
            mask = jnp.asarray(jo.band_mask(nf))
            np.testing.assert_allclose(
                apx_relu_blocks(f, mask, dec, enc),
                ref.apx_relu_blocks(f, mask, dec, enc), atol=1e-4)

    def test_asm_beats_apx_rmse(self):
        """The Fig-4a ordering on random blocks."""
        rng = np.random.default_rng(6)
        dec, enc = mats(Q_FLAT)
        x = jnp.asarray(rng.uniform(-1, 1, (2000, 64)).astype(np.float32))
        f = x @ enc
        truth = np.maximum(np.array(x), 0)
        for nf in (4, 8, 12):
            mask = jnp.asarray(jo.band_mask(nf))
            asm = np.array(asm_relu_blocks(f, mask, dec, enc) @ dec)
            apx = np.array(apx_relu_blocks(f, mask, dec, enc) @ dec)
            rmse_asm = np.sqrt(np.mean((asm - truth) ** 2))
            rmse_apx = np.sqrt(np.mean((apx - truth) ** 2))
            assert rmse_asm < rmse_apx

    def test_grad_exact_at_15(self):
        """At phi=15 the ASM VJP is the exact ReLU subgradient."""
        dec, enc = mats(Q_FLAT)
        f = rand_blocks(7, 128)
        mask = jnp.asarray(jo.band_mask(15))

        def jpeg_loss(ff):
            return jnp.sum(asm_relu_blocks(ff, mask, dec, enc) ** 2)

        def spatial_loss(ff):
            x = ff @ dec
            return jnp.sum((jnp.maximum(x, 0) @ enc) ** 2)

        g1 = jax.grad(jpeg_loss)(f)
        g2 = jax.grad(spatial_loss)(f)
        np.testing.assert_allclose(g1, g2, atol=1e-3)

    @settings(max_examples=20, deadline=None)
    @given(m=st.integers(1, 600), nf=st.integers(1, 15), seed=st.integers(0, 1000))
    def test_hypothesis_sweep(self, m, nf, seed):
        dec, enc = mats(Q_FLAT)
        f = rand_blocks(seed, m)
        mask = jnp.asarray(jo.band_mask(nf))
        np.testing.assert_allclose(
            asm_relu_blocks(f, mask, dec, enc),
            ref.asm_relu_blocks(f, mask, dec, enc), atol=1e-4)


# ---------------------------------------------------------------------------
# block_matmul
# ---------------------------------------------------------------------------
class TestBlockMatmul:
    @pytest.mark.parametrize("m,k,n", [
        (1, 64, 64), (64, 64, 64), (100, 576, 512), (17, 128, 30)])
    def test_matches_ref(self, m, k, n):
        rng = np.random.default_rng(m + k + n)
        a = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
        np.testing.assert_allclose(
            block_matmul(a, b), ref.block_matmul(a, b), atol=1e-3)

    def test_grads(self):
        rng = np.random.default_rng(9)
        a = jnp.asarray(rng.normal(size=(32, 64)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(64, 48)).astype(np.float32))
        ga = jax.grad(lambda aa: jnp.sum(block_matmul(aa, b) ** 2))(a)
        gb = jax.grad(lambda bb: jnp.sum(block_matmul(a, bb) ** 2))(b)
        np.testing.assert_allclose(ga, 2 * (a @ b) @ b.T, atol=1e-2)
        np.testing.assert_allclose(gb, 2 * a.T @ (a @ b), atol=1e-2)

    @settings(max_examples=15, deadline=None)
    @given(m=st.integers(1, 200), k=st.sampled_from([64, 128, 576]),
           n=st.sampled_from([10, 64, 512]), seed=st.integers(0, 100))
    def test_hypothesis_sweep(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        a = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
        np.testing.assert_allclose(
            block_matmul(a, b), ref.block_matmul(a, b), atol=1e-3)
