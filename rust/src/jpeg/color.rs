//! RGB <-> YCbCr conversion (JFIF / BT.601 full-range convention).

/// RGB [0,255] -> YCbCr [0,255] (Cb/Cr centered at 128).
#[inline]
pub fn rgb_to_ycbcr(r: f32, g: f32, b: f32) -> (f32, f32, f32) {
    let y = 0.299 * r + 0.587 * g + 0.114 * b;
    let cb = 128.0 - 0.168_736 * r - 0.331_264 * g + 0.5 * b;
    let cr = 128.0 + 0.5 * r - 0.418_688 * g - 0.081_312 * b;
    (y, cb, cr)
}

/// YCbCr [0,255] -> RGB [0,255].
#[inline]
pub fn ycbcr_to_rgb(y: f32, cb: f32, cr: f32) -> (f32, f32, f32) {
    let cb = cb - 128.0;
    let cr = cr - 128.0;
    let r = y + 1.402 * cr;
    let g = y - 0.344_136 * cb - 0.714_136 * cr;
    let b = y + 1.772 * cb;
    (r, g, b)
}

/// Convert an interleaved-planar RGB image (3, H, W) to YCbCr planes.
pub fn planes_rgb_to_ycbcr(rgb: &[f32], h: usize, w: usize) -> Vec<f32> {
    let hw = h * w;
    assert_eq!(rgb.len(), 3 * hw);
    let mut out = vec![0.0f32; 3 * hw];
    for i in 0..hw {
        let (y, cb, cr) = rgb_to_ycbcr(rgb[i], rgb[hw + i], rgb[2 * hw + i]);
        out[i] = y;
        out[hw + i] = cb;
        out[2 * hw + i] = cr;
    }
    out
}

/// Convert YCbCr planes (3, H, W) back to RGB planes.
pub fn planes_ycbcr_to_rgb(ycc: &[f32], h: usize, w: usize) -> Vec<f32> {
    let hw = h * w;
    assert_eq!(ycc.len(), 3 * hw);
    let mut out = vec![0.0f32; 3 * hw];
    for i in 0..hw {
        let (r, g, b) = ycbcr_to_rgb(ycc[i], ycc[hw + i], ycc[2 * hw + i]);
        out[i] = r;
        out[hw + i] = g;
        out[2 * hw + i] = b;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gray_is_y_only() {
        let (y, cb, cr) = rgb_to_ycbcr(100.0, 100.0, 100.0);
        assert!((y - 100.0).abs() < 1e-3);
        assert!((cb - 128.0).abs() < 1e-3);
        assert!((cr - 128.0).abs() < 1e-3);
    }

    #[test]
    fn roundtrip_pointwise() {
        for (r, g, b) in [(0.0, 0.0, 0.0), (255.0, 255.0, 255.0), (12.0, 200.0, 99.0)] {
            let (y, cb, cr) = rgb_to_ycbcr(r, g, b);
            let (r2, g2, b2) = ycbcr_to_rgb(y, cb, cr);
            assert!((r - r2).abs() < 0.01, "r");
            assert!((g - g2).abs() < 0.01, "g");
            assert!((b - b2).abs() < 0.01, "b");
        }
    }

    #[test]
    fn roundtrip_planes() {
        let mut rng = crate::util::Rng::new(9);
        let (h, w) = (4, 6);
        let rgb: Vec<f32> = (0..3 * h * w).map(|_| rng.uniform_in(0.0, 255.0)).collect();
        let back = planes_ycbcr_to_rgb(&planes_rgb_to_ycbcr(&rgb, h, w), h, w);
        for (a, b) in rgb.iter().zip(&back) {
            assert!((a - b).abs() < 0.01);
        }
    }

    #[test]
    fn primaries() {
        // pure red has high Cr, pure blue high Cb
        let (_, cb_r, cr_r) = rgb_to_ycbcr(255.0, 0.0, 0.0);
        let (_, cb_b, cr_b) = rgb_to_ycbcr(0.0, 0.0, 255.0);
        assert!(cr_r > 200.0 && cb_r < 128.0);
        assert!(cb_b > 200.0 && cr_b < 128.0);
    }
}
