//! L3 coordinator: the serving and training runtime around the AOT
//! artifacts.
//!
//! The serving side is the paper's deployment story: requests arrive as
//! entropy-coded JPEG bytes; the [`router`] picks a pipeline (spatial =
//! full decompression -> pixel network; jpeg = entropy decode only ->
//! coefficient network); the [`batcher`] coalesces requests into the
//! compiled batch shapes; [`metrics`] tracks latency/throughput — the
//! quantities Figure 5 reports.  The [`server::Server`] facade also
//! fronts the native staged pipeline in [`crate::serving`]
//! (`--engine native`), which serves the same requests with no PJRT
//! artifacts at all.
//!
//! The training side ([`training`]) drives the train-step artifacts with
//! synthetic data batches, logging the loss curve and checkpointing
//! through [`crate::params`].
//!
//! No tokio in this environment's vendored crate set: the runtime is
//! std::thread + mpsc, which for a CPU PJRT backend (blocking execute)
//! is the honest architecture anyway.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;
pub mod training;

pub use batcher::{BatcherConfig, DynamicBatcher};
pub use metrics::{LatencyHistogram, Metrics, Snapshot};
pub use router::{Route, Router};
pub use server::{InferRequest, InferResponse, Server, ServerConfig};
pub use training::{TrainConfig, TrainReport, Trainer};
