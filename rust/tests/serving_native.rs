//! Native serving pipeline integration tests: admission backpressure,
//! per-request deadlines, graceful drain, and logits equivalence across
//! kernels — all without PJRT artifacts (same fixture recipe as
//! `sparse_equivalence.rs`: synthetic images -> real encoder ->
//! entropy decode).

use std::time::{Duration, Instant};

use jpegdomain::coordinator::server::Server;
use jpegdomain::data::{Dataset, Split, SynthKind};
use jpegdomain::jpeg::codec;
use jpegdomain::jpeg_domain::network::RESNET_PLAN;
use jpegdomain::jpeg_domain::plan::{Act, DccRef, PlanCtx};
use jpegdomain::jpeg_domain::relu::Method;
use jpegdomain::params::{ModelConfig, ParamSet};
use jpegdomain::serving::{
    NativeEngine, NativeMode, NativePipeline, PipelineConfig, ServeError, ServeRequest,
};
use jpegdomain::tensor::{SparseBlocks, Tensor};

/// A deliberately small model: exploded-map precompute stays cheap in
/// debug test runs while exercising every layer of the pipeline.
fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "tiny".into(),
        in_channels: 1,
        num_classes: 4,
        widths: [2, 2, 2],
        image_size: 32,
    }
}

fn engine(mode: NativeMode, seed: u64) -> NativeEngine {
    let cfg = tiny_cfg();
    let params = ParamSet::init(&cfg, seed);
    NativeEngine::new(cfg, params, 15, Method::Asm, 1, mode)
}

fn quality50_files(n: usize) -> Vec<(Vec<u8>, u32)> {
    Dataset::synthetic(SynthKind::Mnist, 2, n, 16).jpeg_bytes(Split::Test, 50)
}

#[test]
fn backpressure_rejects_with_typed_error_then_drains() {
    // tiny queues + a compute stage that must first pay the exploded
    // precompute (the engine is cold): flooding the admission queue has
    // to produce a typed QueueFull rejection, and shutdown must still
    // answer every admitted request.
    let p = NativePipeline::start(
        engine(NativeMode::Sparse, 1),
        PipelineConfig {
            decode_workers: 1,
            compute_workers: 1,
            queue_capacity: 2,
            decoded_capacity: 1,
            max_batch: 1,
        },
    );
    let files = quality50_files(4);
    let mut receivers = Vec::new();
    let mut rejections = 0usize;
    // far more submissions than total queue space; decode cannot drain
    // into the stalled compute stage faster than we submit
    for i in 0..64 {
        match p.try_submit(files[i % files.len()].0.clone()) {
            Ok(rx) => receivers.push(rx),
            Err(ServeError::QueueFull { capacity }) => {
                assert_eq!(capacity, 2);
                rejections += 1;
            }
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    assert!(rejections > 0, "flooding a capacity-2 queue must reject");
    assert!(!receivers.is_empty(), "some requests are admitted");
    assert_eq!(p.metrics.snapshot().rejected, rejections as u64);

    // graceful drain: every admitted request still gets a reply
    p.shutdown();
    for rx in receivers {
        let resp = rx.recv().expect("reply delivered before shutdown completed");
        let resp = resp.expect("admitted request served");
        assert_eq!(resp.logits.len(), 4);
    }
}

#[test]
fn shutdown_drains_in_flight_requests() {
    let p = NativePipeline::start(
        engine(NativeMode::Sparse, 2),
        PipelineConfig {
            decode_workers: 2,
            compute_workers: 1,
            queue_capacity: 64,
            decoded_capacity: 16,
            max_batch: 4,
        },
    );
    let files = quality50_files(6);
    let receivers: Vec<_> = files
        .iter()
        .map(|(b, _)| p.try_submit(b.clone()).expect("capacity 64"))
        .collect();
    // shut down immediately: the pipeline must finish what it admitted
    p.shutdown();
    for rx in receivers {
        let resp = rx.recv().expect("drained").expect("served");
        assert_eq!(resp.logits.len(), 4);
        assert!(resp.predicted < 4);
    }
}

#[test]
fn native_sparse_dense_and_reference_logits_agree() {
    let files = quality50_files(3);
    // oracle: the non-exploded DCC network on the densified input
    let cis: Vec<_> = files
        .iter()
        .map(|(b, _)| codec::decode_to_coefficients(b).unwrap())
        .collect();
    let qvec = cis[0].qvec(0);
    let f0 = SparseBlocks::from_coeff_images(&cis);
    let cfg = tiny_cfg();
    let params = ParamSet::init(&cfg, 3);
    let ctx = PlanCtx {
        params: &params,
        exploded: None,
        qvec: &qvec,
        num_freqs: 15,
        method: Method::Asm,
    };
    let want = RESNET_PLAN.run(&DccRef, &ctx, &Act::Dense(f0.to_dense()), None);

    let mut got = Vec::new();
    for mode in [NativeMode::Sparse, NativeMode::Dense, NativeMode::SparseResident] {
        let e = NativeEngine::new(cfg.clone(), params.clone(), 15, Method::Asm, 1, mode);
        let p = NativePipeline::start(e, PipelineConfig::default());
        let logits: Vec<Vec<f32>> = files
            .iter()
            .map(|(b, _)| p.infer(b.clone()).unwrap().logits)
            .collect();
        p.shutdown();
        got.push(logits);
    }
    // the resident kernel is not merely close — it is the same arithmetic
    assert_eq!(got[2], got[0], "sparse-resident logits must be bit-identical");
    for (i, (s, d)) in got[0].iter().zip(&got[1]).enumerate() {
        let srow = Tensor::from_vec(&[1, 4], s.clone());
        let drow = Tensor::from_vec(&[1, 4], d.clone());
        let wrow = Tensor::from_vec(
            &[1, 4],
            want.data()[i * 4..(i + 1) * 4].to_vec(),
        );
        assert!(
            srow.max_abs_diff(&drow) < 1e-2,
            "sparse vs dense row {i}: {}",
            srow.max_abs_diff(&drow)
        );
        assert!(
            srow.max_abs_diff(&wrow) < 1e-2,
            "sparse vs reference row {i}: {}",
            srow.max_abs_diff(&wrow)
        );
    }
}

#[test]
fn expired_deadline_rejected_with_typed_error_before_compute() {
    let p = NativePipeline::start(engine(NativeMode::Sparse, 6), PipelineConfig::default());
    let files = quality50_files(1);

    // a deadline that already passed: typed rejection at admission,
    // never enqueued, never decoded, never computed
    let expired = ServeRequest::new(files[0].0.clone())
        .with_deadline(Instant::now() - Duration::from_millis(1));
    match p.try_submit_request(expired) {
        Err(ServeError::DeadlineExceeded) => {}
        Err(e) => panic!("expected DeadlineExceeded, got {e}"),
        Ok(_) => panic!("expired request must not be admitted"),
    }
    let snap = p.metrics.snapshot();
    assert_eq!(snap.deadline_expired, 1);
    assert_eq!(snap.admitted, 0, "expired request never occupied the queue");
    assert_eq!(snap.compute.processed, 0);

    // the error is recoverable through the anyhow reply channel
    // convention too
    let any = anyhow::Error::new(ServeError::DeadlineExceeded);
    assert_eq!(any.downcast_ref::<ServeError>(), Some(&ServeError::DeadlineExceeded));

    // a generous deadline serves normally
    let rx = p
        .try_submit_request(
            ServeRequest::new(files[0].0.clone())
                .with_deadline(Instant::now() + Duration::from_secs(600)),
        )
        .expect("future deadline admits");
    let resp = rx.recv().expect("served").expect("ok");
    assert_eq!(resp.logits.len(), 4);
    assert_eq!(p.metrics.snapshot().deadline_expired, 1, "served request not counted");
    p.shutdown();
}

#[test]
fn server_facade_native_roundtrip_and_tags() {
    let server = Server::start_native(
        engine(NativeMode::Sparse, 4),
        PipelineConfig::default(),
    );
    let q50 = quality50_files(2);
    let q90 = Dataset::synthetic(SynthKind::Mnist, 2, 2, 16).jpeg_bytes(Split::Test, 90);
    for (bytes, _) in q50.iter().chain(&q90) {
        let resp = server.infer(bytes.clone()).unwrap();
        assert_eq!(resp.logits.len(), 4);
        assert!(resp.latency > Duration::ZERO);
    }
    let snap = server.metrics.snapshot();
    assert_eq!(snap.requests, 4);
    let ps = server.pipeline().unwrap().metrics.snapshot();
    assert_eq!(ps.per_tag[0].1, 2, "q50 traffic tracked separately: {ps}");
    assert_eq!(ps.per_tag[2].1, 2, "q90 traffic tracked separately: {ps}");
    assert_eq!(ps.decode.processed, 4);
    assert_eq!(ps.compute.processed, 4);
    server.shutdown();
}

#[test]
fn server_facade_native_bad_request_typed_error() {
    let server = Server::start_native(
        engine(NativeMode::Sparse, 5),
        PipelineConfig::default(),
    );
    let err = server.infer(vec![0, 1, 2]).unwrap_err();
    assert!(
        matches!(err.downcast_ref::<ServeError>(), Some(ServeError::Decode(_))),
        "{err}"
    );
    server.shutdown();
}
