//! Closed-loop serving load generator (`repro serve bench`).
//!
//! Drives the `Server` facade with `clients` synchronous client threads
//! over a mixed-quality JPEG request stream and reports throughput +
//! latency percentiles per engine: native-sparse-resident (activations
//! stay sparse between layers; includes per-layer nonzero fractions),
//! native-sparse (dense-boundary), native-dense, and — when PJRT
//! artifacts are present — the pjrt worker loop.  Emits a JSON report
//! (rows + the axpy-tiling kernel ablation) so successive PRs keep a
//! serving-perf trajectory.
//!
//! With [`BenchOptions::remote`] set (`serve bench --remote ADDR`), the
//! same request stream is driven over the socket front end through the
//! blocking [`crate::serving::frontend::Client`] — one connection per
//! thread, [`BenchOptions::connections`] threads (default `clients`),
//! latency measured wire to wire and attributed per encoded quality —
//! next to one in-process sparse-resident row, so the report
//! (`BENCH_PR9.json`) prices the network boundary itself.  Typed sheds
//! are tallied per code (`queue_full`, `deadline_exceeded`,
//! `rate_limited`) and printed on one greppable line, so an overload
//! run shows *graceful* degradation, not a mystery error count.
//!
//! Every row also carries **server-side** percentiles read from the
//! serving process's log-bucketed latency histograms: in-process rows
//! straight off the aggregate registry, the remote row via a stats
//! scrape ([`crate::serving::frontend::Client::stats`]) of the
//! `jd_request_e2e_us` family.  Client-side minus server-side is the
//! wire + framing overhead, now visible per run.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Instant;

use crate::bench_harness::throughput::AxpyReport;
use crate::coordinator::router::Route;
use crate::coordinator::server::{Server, ServerConfig};
use crate::data::{Dataset, Split, SynthKind};
use crate::jpeg_domain::relu::Method;
use crate::json::Json;

use super::engine::{NativeEngine, NativeMode};
use super::pipeline::PipelineConfig;

/// Load-generator settings.
#[derive(Clone, Debug)]
pub struct BenchOptions {
    pub dataset: String,
    pub requests: usize,
    pub clients: usize,
    pub qualities: Vec<u8>,
    pub seed: u64,
    pub threads: usize,
    pub pipeline: PipelineConfig,
    pub artifacts: PathBuf,
    /// Skip the dense-kernel baseline (it is much slower).
    pub skip_dense: bool,
    /// Drive a running socket front end at this address instead of the
    /// full engine sweep (one in-process sparse-resident row stays as
    /// the baseline the socket row is compared against).
    pub remote: Option<String>,
    /// Concurrent connections for the remote row (one `Client` per
    /// thread); 0 means "same as `clients`".  Raising it past the
    /// server's capacity is the intended overload experiment: the extra
    /// connections shed with typed codes instead of queueing unbounded.
    pub connections: usize,
}

impl Default for BenchOptions {
    fn default() -> Self {
        BenchOptions {
            dataset: "mnist".into(),
            requests: 200,
            clients: 4,
            qualities: vec![50, 75, 90],
            seed: 0,
            threads: 0,
            pipeline: PipelineConfig::default(),
            artifacts: PathBuf::from("artifacts"),
            skip_dense: false,
            remote: None,
            connections: 0,
        }
    }
}

impl BenchOptions {
    /// Default report filename for this run's mode (shared by the CLI
    /// and `examples/serve_requests.rs` so the artifact names cannot
    /// drift apart).
    pub fn default_out(&self) -> &'static str {
        if self.remote.is_some() { "BENCH_PR9.json" } else { "BENCH_PR2.json" }
    }

    /// Whether the axpy kernel ablation belongs to this run: it
    /// measures the in-process kernel sweep, not the wire comparison.
    pub fn wants_axpy(&self) -> bool {
        self.remote.is_none()
    }

    /// Effective remote connection count (`connections`, falling back
    /// to `clients`, never zero).
    pub fn remote_connections(&self) -> usize {
        if self.connections > 0 { self.connections } else { self.clients.max(1) }
    }
}

/// One engine's measured row.
#[derive(Clone, Debug)]
pub struct BenchRow {
    pub engine: String,
    pub requests: u64,
    /// Requests actually answered with logits.  Not derivable from
    /// `requests - errors`: a remote client thread that loses its
    /// connection stops attempting, so its tail is neither served nor
    /// errored.
    pub completed: u64,
    pub errors: u64,
    pub rejected: u64,
    /// Requests shed because their deadline budget ran out before
    /// compute (remote row only; subset of `errors`).
    pub deadline_exceeded: u64,
    /// Requests refused by the per-connection token bucket (remote row
    /// only; subset of `errors`).
    pub rate_limited: u64,
    /// Framing violations seen by the client (remote row only; a
    /// healthy server keeps this at zero).
    pub protocol_errors: u64,
    pub throughput: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    /// Server-side percentiles from the serving process's log-bucketed
    /// latency histogram (`jd_request_e2e_us` over the wire, the
    /// aggregate registry in process); `0.0` when the scrape failed.
    pub server_p50_ms: f64,
    pub server_p90_ms: f64,
    pub server_p99_ms: f64,
    /// (tag label, requests, p50 ms) — native engines only.
    pub per_tag: Vec<(String, u64, f64)>,
    /// (layer label, nonzero fraction) — sparse-resident engine only.
    pub layer_nonzero: Vec<(String, f64)>,
}

/// Mixed-quality request stream: request i is encoded at
/// `qualities[i % qualities.len()]`.
fn request_stream(opts: &BenchOptions) -> anyhow::Result<Vec<Vec<u8>>> {
    let kind = SynthKind::parse(&opts.dataset)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {:?}", opts.dataset))?;
    let data = Dataset::synthetic(kind, 2, opts.requests, opts.seed.wrapping_add(17));
    let per_quality: Vec<Vec<(Vec<u8>, u32)>> = opts
        .qualities
        .iter()
        .map(|&q| data.jpeg_bytes(Split::Test, q))
        .collect();
    anyhow::ensure!(!per_quality.is_empty(), "need at least one quality");
    Ok((0..opts.requests)
        .map(|i| per_quality[i % per_quality.len()][i % per_quality[0].len()].0.clone())
        .collect())
}

/// Drive `files` through `server` from `clients` synchronous threads.
/// Returns (wall seconds, error count).
fn closed_loop(server: &Server, files: &[Vec<u8>], clients: usize) -> (f64, u64) {
    let clients = clients.max(1);
    let t0 = Instant::now();
    let errors: u64 = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|t| {
                s.spawn(move || {
                    let mut errs = 0u64;
                    for i in (t..files.len()).step_by(clients) {
                        if server.infer(files[i].clone()).is_err() {
                            errs += 1;
                        }
                    }
                    errs
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).sum()
    });
    (t0.elapsed().as_secs_f64(), errors)
}

fn measure(server: &Server, name: &str, files: &[Vec<u8>], clients: usize) -> BenchRow {
    let (wall, errors) = closed_loop(server, files, clients);
    let snap = server.metrics.snapshot();
    let (rejected, per_tag, layer_nonzero) = match server.pipeline() {
        Some(p) => {
            let ps = p.metrics.snapshot();
            (
                ps.rejected,
                ps.per_tag
                    .iter()
                    .filter(|(_, n, _)| *n > 0)
                    .map(|(t, n, p50)| (t.label().to_string(), *n, *p50))
                    .collect(),
                ps.layer_nonzero
                    .iter()
                    .map(|(l, d)| (l.to_string(), *d))
                    .collect(),
            )
        }
        None => (0, Vec::new(), Vec::new()),
    };
    // server-side view of the same traffic, straight off the
    // log-bucketed histogram the registry scrape exposes
    let h = &server.metrics.request_latency;
    BenchRow {
        engine: name.to_string(),
        requests: files.len() as u64,
        // the closed loop attempts every request, so here (unlike the
        // remote row) completed really is total minus errors
        completed: (files.len() as u64).saturating_sub(errors),
        errors,
        rejected,
        deadline_exceeded: 0,
        rate_limited: 0,
        protocol_errors: 0,
        // served requests only: rejected/errored ones cost ~no wall
        // time and would inflate req/s exactly when shedding load
        throughput: (files.len() as u64).saturating_sub(errors) as f64 / wall,
        p50_ms: snap.p50_ms,
        p99_ms: snap.p99_ms,
        mean_ms: snap.mean_ms,
        server_p50_ms: h.quantile_us(0.50) / 1e3,
        server_p90_ms: h.quantile_us(0.90) / 1e3,
        server_p99_ms: h.quantile_us(0.99) / 1e3,
        per_tag,
        layer_nonzero,
    }
}

fn native_row(
    opts: &BenchOptions,
    files: &[Vec<u8>],
    mode: NativeMode,
) -> anyhow::Result<BenchRow> {
    let name = match mode {
        NativeMode::Sparse => "native-sparse",
        NativeMode::Dense => "native-dense",
        NativeMode::SparseResident => "native-sparse-resident",
    };
    let engine = NativeEngine::from_preset(
        &opts.dataset,
        None,
        opts.seed,
        15,
        Method::Asm,
        opts.threads,
        mode,
    )?;
    let server = Server::start_native(engine, opts.pipeline);
    for &q in &opts.qualities {
        if let Some(p) = server.pipeline() {
            p.warm(q);
        }
    }
    let row = measure(&server, name, files, opts.clients);
    server.shutdown();
    Ok(row)
}

/// Sorted-sample quantile in milliseconds (client-side latencies; the
/// in-process rows read the server's log-bucketed histograms instead).
fn quantile_ms(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let i = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len()) - 1;
    sorted[i]
}

/// Drive a running socket front end closed-loop: one connection per
/// thread ([`BenchOptions::remote_connections`] of them), wire-to-wire
/// latency attributed per encoded quality, typed sheds tallied per code.
fn remote_row(opts: &BenchOptions, files: &[Vec<u8>], addr: &str) -> anyhow::Result<BenchRow> {
    use crate::serving::frontend::{Client, ClientError, WireCode};
    let clients = opts.remote_connections();
    let nq = opts.qualities.len().max(1);
    let t0 = Instant::now();
    /// Per-thread tally: latency samples plus the typed-shed breakdown.
    #[derive(Default)]
    struct ThreadOut {
        /// (latency ms, quality index) per completed request.
        samples: Vec<(f64, usize)>,
        errors: u64,
        rejected: u64,
        deadline_exceeded: u64,
        rate_limited: u64,
        protocol: u64,
    }
    let outs: Vec<ThreadOut> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|t| {
                s.spawn(move || -> anyhow::Result<ThreadOut> {
                    let mut client = Client::connect(addr)
                        .map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
                    let mut out = ThreadOut::default();
                    for i in (t..files.len()).step_by(clients) {
                        let w0 = Instant::now();
                        match client.infer(&files[i]) {
                            Ok(_) => {
                                out.samples.push((w0.elapsed().as_secs_f64() * 1e3, i % nq));
                            }
                            Err(ClientError::Serve { code, .. }) => {
                                out.errors += 1;
                                match code {
                                    WireCode::QueueFull => out.rejected += 1,
                                    WireCode::DeadlineExceeded => out.deadline_exceeded += 1,
                                    WireCode::RateLimited => out.rate_limited += 1,
                                    _ => {}
                                }
                            }
                            Err(ClientError::Protocol(_)) => {
                                out.protocol += 1;
                                out.errors += 1;
                                break; // framing broke; this connection is done
                            }
                            Err(_) => {
                                out.errors += 1;
                                break; // transport gone
                            }
                        }
                    }
                    Ok(out)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("client thread"))
            .collect::<anyhow::Result<Vec<_>>>()
    })?;
    let wall = t0.elapsed().as_secs_f64();

    let mut all_ms: Vec<f64> = Vec::new();
    let mut per_q: Vec<Vec<f64>> = vec![Vec::new(); nq];
    let (mut errors, mut rejected, mut protocol_errors) = (0u64, 0u64, 0u64);
    let (mut deadline_exceeded, mut rate_limited) = (0u64, 0u64);
    for out in outs {
        errors += out.errors;
        rejected += out.rejected;
        deadline_exceeded += out.deadline_exceeded;
        rate_limited += out.rate_limited;
        protocol_errors += out.protocol;
        for (ms, qi) in out.samples {
            all_ms.push(ms);
            per_q[qi].push(ms);
        }
    }
    all_ms.sort_by(|a, b| a.partial_cmp(b).expect("finite latency"));
    let completed = all_ms.len() as u64;
    let mean_ms = if all_ms.is_empty() {
        0.0
    } else {
        all_ms.iter().sum::<f64>() / all_ms.len() as f64
    };
    let per_tag = opts
        .qualities
        .iter()
        .zip(&mut per_q)
        .filter(|(_, v)| !v.is_empty())
        .map(|(&q, v)| {
            v.sort_by(|a, b| a.partial_cmp(b).expect("finite latency"));
            (format!("q{q}"), v.len() as u64, quantile_ms(v, 0.50))
        })
        .collect();

    // server-side view of the same traffic: one stats scrape over a
    // fresh connection, after the load has drained
    let (server_p50_ms, server_p90_ms, server_p99_ms) = match Client::connect(addr)
        .map_err(ClientError::Io)
        .and_then(|mut c| c.stats())
    {
        Ok(text) => {
            let scrape = crate::telemetry::Scrape::parse(&text);
            let q = |p| scrape.histogram_quantile("jd_request_e2e_us", &[], p) / 1e3;
            (q(0.50), q(0.90), q(0.99))
        }
        Err(e) => {
            eprintln!("serve bench: stats scrape failed ({e}); server percentiles read 0");
            (0.0, 0.0, 0.0)
        }
    };

    Ok(BenchRow {
        engine: "remote-socket".to_string(),
        requests: files.len() as u64,
        completed,
        errors,
        rejected,
        deadline_exceeded,
        rate_limited,
        protocol_errors,
        throughput: completed as f64 / wall,
        p50_ms: quantile_ms(&all_ms, 0.50),
        p99_ms: quantile_ms(&all_ms, 0.99),
        mean_ms,
        server_p50_ms,
        server_p90_ms,
        server_p99_ms,
        per_tag,
        layer_nonzero: Vec::new(),
    })
}

/// Run the full comparison.  Returns the measured rows plus a note for
/// every engine that was skipped (e.g. pjrt with no artifacts).
///
/// In `--remote` mode the sweep is the socket row plus the in-process
/// sparse-resident baseline; the other engines are reported as skipped
/// so the JSON shape stays stable.
pub fn run(opts: &BenchOptions) -> anyhow::Result<(Vec<BenchRow>, Vec<(String, String)>)> {
    let files = request_stream(opts)?;
    let mut rows = Vec::new();
    let mut skipped = Vec::new();

    if let Some(addr) = &opts.remote {
        rows.push(remote_row(opts, &files, addr)?);
        rows.push(native_row(opts, &files, NativeMode::SparseResident)?);
        for engine in ["native-sparse", "native-dense", "pjrt"] {
            skipped.push((engine.to_string(), "skipped in --remote mode".to_string()));
        }
        return Ok((rows, skipped));
    }

    rows.push(native_row(opts, &files, NativeMode::SparseResident)?);
    rows.push(native_row(opts, &files, NativeMode::Sparse)?);
    if opts.skip_dense {
        skipped.push(("native-dense".to_string(), "skipped by flag".to_string()));
    } else {
        rows.push(native_row(opts, &files, NativeMode::Dense)?);
    }

    // the pjrt engine needs real artifacts + a linked PJRT backend;
    // probe before spawning so a missing backend is a skip, not a hang
    match crate::runtime::Engine::new(&opts.artifacts) {
        Ok(_) => {
            let server = Server::start_default(
                opts.artifacts.clone(),
                opts.dataset.clone(),
                None,
                opts.seed,
                ServerConfig { route: Route::Jpeg, ..Default::default() },
            );
            rows.push(measure(&server, "pjrt", &files, opts.clients));
            server.shutdown();
        }
        Err(e) => skipped.push(("pjrt".to_string(), e.to_string())),
    }
    Ok((rows, skipped))
}

/// Render rows (+ optionally the axpy kernel ablation) into the bench
/// JSON document — `BENCH_PR2.json` for the engine sweep,
/// `BENCH_PR7.json` for the remote-vs-in-process comparison (which has
/// no kernel ablation to attach).
pub fn report_json(
    opts: &BenchOptions,
    rows: &[BenchRow],
    skipped: &[(String, String)],
    axpy_report: Option<&AxpyReport>,
) -> Json {
    let num = Json::Num;
    let mut doc = BTreeMap::new();

    let mut config = BTreeMap::new();
    config.insert("dataset".into(), Json::Str(opts.dataset.clone()));
    config.insert("requests".into(), num(opts.requests as f64));
    config.insert("clients".into(), num(opts.clients as f64));
    config.insert("connections".into(), num(opts.remote_connections() as f64));
    config.insert(
        "qualities".into(),
        Json::Arr(opts.qualities.iter().map(|&q| num(q as f64)).collect()),
    );
    config.insert("max_batch".into(), num(opts.pipeline.max_batch as f64));
    config.insert("decode_workers".into(), num(opts.pipeline.decode_workers as f64));
    config.insert("compute_workers".into(), num(opts.pipeline.compute_workers as f64));
    if let Some(addr) = &opts.remote {
        config.insert("remote".into(), Json::Str(addr.clone()));
    }
    doc.insert("config".into(), Json::Obj(config));

    let mut out_rows = Vec::new();
    for r in rows {
        let mut o = BTreeMap::new();
        o.insert("engine".into(), Json::Str(r.engine.clone()));
        o.insert("requests".into(), num(r.requests as f64));
        o.insert("completed".into(), num(r.completed as f64));
        o.insert("errors".into(), num(r.errors as f64));
        o.insert("rejected".into(), num(r.rejected as f64));
        o.insert("deadline_exceeded".into(), num(r.deadline_exceeded as f64));
        o.insert("rate_limited".into(), num(r.rate_limited as f64));
        o.insert("protocol_errors".into(), num(r.protocol_errors as f64));
        o.insert("throughput".into(), num(r.throughput));
        o.insert("p50_ms".into(), num(r.p50_ms));
        o.insert("p99_ms".into(), num(r.p99_ms));
        o.insert("mean_ms".into(), num(r.mean_ms));
        o.insert("server_p50_ms".into(), num(r.server_p50_ms));
        o.insert("server_p90_ms".into(), num(r.server_p90_ms));
        o.insert("server_p99_ms".into(), num(r.server_p99_ms));
        let mut tags = BTreeMap::new();
        for (label, n, p50) in &r.per_tag {
            let mut t = BTreeMap::new();
            t.insert("requests".into(), num(*n as f64));
            t.insert("p50_ms".into(), num(*p50));
            tags.insert(label.clone(), Json::Obj(t));
        }
        o.insert("tags".into(), Json::Obj(tags));
        if !r.layer_nonzero.is_empty() {
            let mut layers = BTreeMap::new();
            for (label, d) in &r.layer_nonzero {
                layers.insert(label.clone(), num(*d));
            }
            o.insert("layer_nonzero".into(), Json::Obj(layers));
        }
        out_rows.push(Json::Obj(o));
    }
    for (engine, why) in skipped {
        let mut o = BTreeMap::new();
        o.insert("engine".into(), Json::Str(engine.clone()));
        o.insert("skipped".into(), Json::Str(why.clone()));
        out_rows.push(Json::Obj(o));
    }
    doc.insert("rows".into(), Json::Arr(out_rows));

    // the axpy inner-loop tiling before/after (unroll 4 vs 8), when the
    // caller measured it (the engine sweep does; the remote run doesn't)
    if let Some(a) = axpy_report {
        let mut axpy = BTreeMap::new();
        axpy.insert("quality".into(), num(a.quality as f64));
        axpy.insert("batch".into(), num(a.batch as f64));
        axpy.insert("cout".into(), num(a.cout as f64));
        axpy.insert("density".into(), num(a.density));
        axpy.insert("unroll4_blocks_per_sec".into(), num(a.unroll4_blocks_per_sec));
        axpy.insert("unroll8_blocks_per_sec".into(), num(a.unroll8_blocks_per_sec));
        axpy.insert("speedup_8_vs_4".into(), num(a.speedup));
        axpy.insert("max_abs_diff".into(), num(a.max_abs_diff as f64));
        doc.insert("axpy_tiling".into(), Json::Obj(axpy));
    }

    Json::Obj(doc)
}

/// Human-readable summary table.
pub fn print_rows(rows: &[BenchRow], skipped: &[(String, String)]) {
    crate::bench_harness::print_table(
        "Serving bench — closed-loop throughput + latency",
        &[
            "engine", "req/s", "p50 ms", "p99 ms", "mean ms", "srv p50", "srv p90", "srv p99",
            "errors", "rejected",
        ],
        &rows
            .iter()
            .map(|r| {
                vec![
                    r.engine.clone(),
                    format!("{:.1}", r.throughput),
                    format!("{:.2}", r.p50_ms),
                    format!("{:.2}", r.p99_ms),
                    format!("{:.2}", r.mean_ms),
                    format!("{:.2}", r.server_p50_ms),
                    format!("{:.2}", r.server_p90_ms),
                    format!("{:.2}", r.server_p99_ms),
                    r.errors.to_string(),
                    r.rejected.to_string(),
                ]
            })
            .collect::<Vec<_>>(),
    );
    for r in rows {
        if !r.per_tag.is_empty() {
            let tags: Vec<String> = r
                .per_tag
                .iter()
                .map(|(l, n, p50)| format!("{l}={n} (p50 {p50:.2}ms)"))
                .collect();
            println!("  {} traffic: {}", r.engine, tags.join(" "));
        }
        if !r.layer_nonzero.is_empty() {
            let layers: Vec<String> = r
                .layer_nonzero
                .iter()
                .map(|(l, d)| format!("{l}={d:.3}"))
                .collect();
            println!("  {} nonzero fraction: {}", r.engine, layers.join(" "));
        }
        if r.engine == "remote-socket" {
            // the one-line health check ci.sh's socket-smoke greps;
            // `completed` counts replies actually received, so a crash
            // that strands unattempted requests cannot fake health
            println!(
                "remote completed requests: {} (protocol errors: {})",
                r.completed, r.protocol_errors
            );
            // the shed breakdown ci.sh's shard-smoke greps: an overload
            // run must shed with *typed* codes, not transport failures
            println!(
                "remote shed: queue_full={} deadline_exceeded={} rate_limited={}",
                r.rejected, r.deadline_exceeded, r.rate_limited
            );
        }
    }
    for (engine, why) in skipped {
        println!("  {engine}: skipped ({why})");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_mixes_qualities() {
        let opts = BenchOptions {
            requests: 6,
            qualities: vec![50, 90],
            ..Default::default()
        };
        let files = request_stream(&opts).unwrap();
        assert_eq!(files.len(), 6);
        // alternating qualities produce different byte streams
        assert_ne!(files[0], files[1]);
    }

    #[test]
    fn report_json_shape() {
        let opts = BenchOptions::default();
        let rows = vec![BenchRow {
            engine: "native-sparse".into(),
            requests: 10,
            completed: 10,
            errors: 0,
            rejected: 0,
            deadline_exceeded: 0,
            rate_limited: 0,
            protocol_errors: 0,
            throughput: 100.0,
            p50_ms: 1.0,
            p99_ms: 2.0,
            mean_ms: 1.2,
            server_p50_ms: 0.9,
            server_p90_ms: 1.5,
            server_p99_ms: 1.8,
            per_tag: vec![("q50".into(), 10, 1.0)],
            layer_nonzero: vec![("input".into(), 0.25), ("stem.relu".into(), 0.5)],
        }];
        let skipped = vec![("pjrt".into(), "no artifacts".into())];
        let axpy = AxpyReport {
            quality: 50,
            batch: 8,
            cout: 16,
            density: 0.25,
            unroll4_blocks_per_sec: 1.0e6,
            unroll8_blocks_per_sec: 1.2e6,
            speedup: 1.2,
            max_abs_diff: 1e-6,
        };
        let doc = report_json(&opts, &rows, &skipped, Some(&axpy));
        let rows_v = doc.get("rows").as_arr().unwrap();
        assert_eq!(rows_v.len(), 2);
        assert_eq!(rows_v[0].get("engine").as_str(), Some("native-sparse"));
        assert_eq!(rows_v[1].get("skipped").as_str(), Some("no artifacts"));
        assert!(rows_v[0].get("layer_nonzero").get("input").as_f64().is_some());
        assert_eq!(rows_v[0].get("protocol_errors").as_f64(), Some(0.0));
        assert_eq!(rows_v[0].get("server_p50_ms").as_f64(), Some(0.9));
        assert_eq!(rows_v[0].get("server_p99_ms").as_f64(), Some(1.8));
        assert!(doc.get("axpy_tiling").get("unroll8_blocks_per_sec").as_f64().is_some());
        // round-trips through the parser
        let text = doc.to_string();
        assert!(crate::json::parse(&text).is_ok());
    }

    #[test]
    fn report_json_remote_shape() {
        let opts = BenchOptions {
            remote: Some("127.0.0.1:7878".into()),
            ..Default::default()
        };
        let rows = vec![BenchRow {
            engine: "remote-socket".into(),
            requests: 12,
            completed: 11,
            errors: 1,
            rejected: 1,
            deadline_exceeded: 0,
            rate_limited: 1,
            protocol_errors: 0,
            throughput: 40.0,
            p50_ms: 2.0,
            p99_ms: 5.0,
            mean_ms: 2.5,
            server_p50_ms: 1.4,
            server_p90_ms: 3.0,
            server_p99_ms: 4.1,
            per_tag: vec![("q50".into(), 4, 2.0), ("q90".into(), 4, 2.2)],
            layer_nonzero: vec![],
        }];
        let doc = report_json(&opts, &rows, &[], None);
        assert_eq!(doc.get("config").get("remote").as_str(), Some("127.0.0.1:7878"));
        let rows_v = doc.get("rows").as_arr().unwrap();
        assert_eq!(rows_v[0].get("engine").as_str(), Some("remote-socket"));
        assert_eq!(rows_v[0].get("completed").as_f64(), Some(11.0));
        assert_eq!(rows_v[0].get("rate_limited").as_f64(), Some(1.0));
        assert_eq!(rows_v[0].get("server_p90_ms").as_f64(), Some(3.0));
        assert_eq!(doc.get("config").get("connections").as_f64(), Some(4.0));
        assert_eq!(
            doc.get("axpy_tiling"),
            &crate::json::Json::Null,
            "no kernel ablation in remote mode"
        );
        assert!(crate::json::parse(&doc.to_string()).is_ok());
    }

    #[test]
    fn quantile_ms_picks_sorted_samples() {
        assert_eq!(quantile_ms(&[], 0.5), 0.0);
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile_ms(&v, 0.50), 2.0);
        assert_eq!(quantile_ms(&v, 0.99), 4.0);
        assert_eq!(quantile_ms(&v, 0.0), 1.0);
    }
}
