//! Baseline JPEG Huffman coding (ITU-T T.81 Annex C + K.3).
//!
//! Tables are specified as (BITS, HUFFVAL): number of codes per length
//! 1..=16 plus the symbol list.  Codes are canonical.  Decode uses the
//! classic per-length (mincode, maxcode, valptr) walk plus an 8-bit
//! lookup fast path — the Huffman decode loop is the serial hot spot of
//! the spatial pipeline, which is exactly the cost the paper's system
//! shares between both routes (entropy decoding is common) while the
//! spatial route additionally pays dequantize+IDCT.

use super::{JpegError, Result};
use super::bits::{BitReader, BitWriter};

/// A Huffman table specification (BITS counts + symbol values).
#[derive(Clone, Debug)]
pub struct HuffSpec {
    pub counts: [u8; 16],
    pub values: Vec<u8>,
}

/// Annex K.3.1 — luminance DC.
pub fn dc_luma_spec() -> HuffSpec {
    HuffSpec {
        counts: [0, 1, 5, 1, 1, 1, 1, 1, 1, 0, 0, 0, 0, 0, 0, 0],
        values: vec![0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11],
    }
}

/// Annex K.3.1 — chrominance DC.
pub fn dc_chroma_spec() -> HuffSpec {
    HuffSpec {
        counts: [0, 3, 1, 1, 1, 1, 1, 1, 1, 1, 1, 0, 0, 0, 0, 0],
        values: vec![0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11],
    }
}

/// Annex K.3.2 — luminance AC.
pub fn ac_luma_spec() -> HuffSpec {
    HuffSpec {
        counts: [0, 2, 1, 3, 3, 2, 4, 3, 5, 5, 4, 4, 0, 0, 1, 0x7D],
        values: vec![
            0x01, 0x02, 0x03, 0x00, 0x04, 0x11, 0x05, 0x12, 0x21, 0x31, 0x41,
            0x06, 0x13, 0x51, 0x61, 0x07, 0x22, 0x71, 0x14, 0x32, 0x81, 0x91,
            0xA1, 0x08, 0x23, 0x42, 0xB1, 0xC1, 0x15, 0x52, 0xD1, 0xF0, 0x24,
            0x33, 0x62, 0x72, 0x82, 0x09, 0x0A, 0x16, 0x17, 0x18, 0x19, 0x1A,
            0x25, 0x26, 0x27, 0x28, 0x29, 0x2A, 0x34, 0x35, 0x36, 0x37, 0x38,
            0x39, 0x3A, 0x43, 0x44, 0x45, 0x46, 0x47, 0x48, 0x49, 0x4A, 0x53,
            0x54, 0x55, 0x56, 0x57, 0x58, 0x59, 0x5A, 0x63, 0x64, 0x65, 0x66,
            0x67, 0x68, 0x69, 0x6A, 0x73, 0x74, 0x75, 0x76, 0x77, 0x78, 0x79,
            0x7A, 0x83, 0x84, 0x85, 0x86, 0x87, 0x88, 0x89, 0x8A, 0x92, 0x93,
            0x94, 0x95, 0x96, 0x97, 0x98, 0x99, 0x9A, 0xA2, 0xA3, 0xA4, 0xA5,
            0xA6, 0xA7, 0xA8, 0xA9, 0xAA, 0xB2, 0xB3, 0xB4, 0xB5, 0xB6, 0xB7,
            0xB8, 0xB9, 0xBA, 0xC2, 0xC3, 0xC4, 0xC5, 0xC6, 0xC7, 0xC8, 0xC9,
            0xCA, 0xD2, 0xD3, 0xD4, 0xD5, 0xD6, 0xD7, 0xD8, 0xD9, 0xDA, 0xE1,
            0xE2, 0xE3, 0xE4, 0xE5, 0xE6, 0xE7, 0xE8, 0xE9, 0xEA, 0xF1, 0xF2,
            0xF3, 0xF4, 0xF5, 0xF6, 0xF7, 0xF8, 0xF9, 0xFA,
        ],
    }
}

/// Annex K.3.2 — chrominance AC.
pub fn ac_chroma_spec() -> HuffSpec {
    HuffSpec {
        counts: [0, 2, 1, 2, 4, 4, 3, 4, 7, 5, 4, 4, 0, 1, 2, 0x77],
        values: vec![
            0x00, 0x01, 0x02, 0x03, 0x11, 0x04, 0x05, 0x21, 0x31, 0x06, 0x12,
            0x41, 0x51, 0x07, 0x61, 0x71, 0x13, 0x22, 0x32, 0x81, 0x08, 0x14,
            0x42, 0x91, 0xA1, 0xB1, 0xC1, 0x09, 0x23, 0x33, 0x52, 0xF0, 0x15,
            0x62, 0x72, 0xD1, 0x0A, 0x16, 0x24, 0x34, 0xE1, 0x25, 0xF1, 0x17,
            0x18, 0x19, 0x1A, 0x26, 0x27, 0x28, 0x29, 0x2A, 0x35, 0x36, 0x37,
            0x38, 0x39, 0x3A, 0x43, 0x44, 0x45, 0x46, 0x47, 0x48, 0x49, 0x4A,
            0x53, 0x54, 0x55, 0x56, 0x57, 0x58, 0x59, 0x5A, 0x63, 0x64, 0x65,
            0x66, 0x67, 0x68, 0x69, 0x6A, 0x73, 0x74, 0x75, 0x76, 0x77, 0x78,
            0x79, 0x7A, 0x82, 0x83, 0x84, 0x85, 0x86, 0x87, 0x88, 0x89, 0x8A,
            0x92, 0x93, 0x94, 0x95, 0x96, 0x97, 0x98, 0x99, 0x9A, 0xA2, 0xA3,
            0xA4, 0xA5, 0xA6, 0xA7, 0xA8, 0xA9, 0xAA, 0xB2, 0xB3, 0xB4, 0xB5,
            0xB6, 0xB7, 0xB8, 0xB9, 0xBA, 0xC2, 0xC3, 0xC4, 0xC5, 0xC6, 0xC7,
            0xC8, 0xC9, 0xCA, 0xD2, 0xD3, 0xD4, 0xD5, 0xD6, 0xD7, 0xD8, 0xD9,
            0xDA, 0xE2, 0xE3, 0xE4, 0xE5, 0xE6, 0xE7, 0xE8, 0xE9, 0xEA, 0xF2,
            0xF3, 0xF4, 0xF5, 0xF6, 0xF7, 0xF8, 0xF9, 0xFA,
        ],
    }
}

/// Encoder side: symbol -> (code, length).
#[derive(Clone, Debug)]
pub struct HuffEncoder {
    code: [u16; 256],
    len: [u8; 256],
}

impl HuffEncoder {
    pub fn new(spec: &HuffSpec) -> Self {
        let mut enc = HuffEncoder { code: [0; 256], len: [0; 256] };
        let mut code = 0u16;
        let mut vi = 0usize;
        for l in 0..16 {
            for _ in 0..spec.counts[l] {
                let sym = spec.values[vi] as usize;
                enc.code[sym] = code;
                enc.len[sym] = (l + 1) as u8;
                code += 1;
                vi += 1;
            }
            code <<= 1;
        }
        enc
    }

    #[inline]
    pub fn emit(&self, w: &mut BitWriter, symbol: u8) {
        let l = self.len[symbol as usize];
        debug_assert!(l > 0, "symbol {symbol:#x} has no code");
        w.put(self.code[symbol as usize] as u32, l as u32);
    }

    pub fn code_len(&self, symbol: u8) -> u8 {
        self.len[symbol as usize]
    }
}

/// Decoder side: canonical (mincode/maxcode/valptr) + 8-bit fast lookup.
#[derive(Clone, Debug)]
pub struct HuffDecoder {
    mincode: [i32; 17],
    maxcode: [i32; 17],
    valptr: [usize; 17],
    values: Vec<u8>,
    /// fast path: (symbol, length) for every 8-bit prefix; len=0 -> slow path
    fast: [(u8, u8); 256],
}

impl HuffDecoder {
    pub fn new(spec: &HuffSpec) -> Self {
        let mut mincode = [0i32; 17];
        let mut maxcode = [-1i32; 17];
        let mut valptr = [0usize; 17];
        let mut code = 0i32;
        let mut vi = 0usize;
        for l in 1..=16 {
            valptr[l] = vi;
            mincode[l] = code;
            let n = spec.counts[l - 1] as usize;
            code += n as i32;
            vi += n;
            maxcode[l] = code - 1;
            code <<= 1;
        }
        let mut dec = HuffDecoder {
            mincode,
            maxcode,
            valptr,
            values: spec.values.clone(),
            fast: [(0, 0); 256],
        };
        // build the 8-bit lookup
        let mut c = 0i32;
        let mut vi = 0usize;
        for l in 1..=8u32 {
            for _ in 0..spec.counts[(l - 1) as usize] {
                let sym = spec.values[vi];
                let shift = 8 - l;
                let lo = (c << shift) as usize;
                for e in 0..(1usize << shift) {
                    dec.fast[lo + e] = (sym, l as u8);
                }
                c += 1;
                vi += 1;
            }
            c <<= 1;
        }
        dec
    }

    /// Decode one symbol from the bit reader.
    pub fn decode(&self, r: &mut BitReader) -> Result<u8> {
        let peek = r.peek16()?;
        let (sym, l) = self.fast[(peek >> 8) as usize];
        if l > 0 {
            r.skip(l as u32)?;
            return Ok(sym);
        }
        // slow path: lengths 9..=16
        let mut code = (peek >> 8) as i32;
        let mut l = 8u32;
        loop {
            l += 1;
            if l > 16 {
                return Err(JpegError::Invalid("bad huffman code".into()));
            }
            code = (code << 1) | ((peek >> (16 - l)) & 1) as i32;
            if code <= self.maxcode[l as usize] {
                let idx = self.valptr[l as usize]
                    + (code - self.mincode[l as usize]) as usize;
                let sym = self.values[idx];
                r.skip(l)?;
                return Ok(sym);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(spec: &HuffSpec, symbols: &[u8]) {
        let enc = HuffEncoder::new(spec);
        let dec = HuffDecoder::new(spec);
        let mut w = BitWriter::new();
        for &s in symbols {
            enc.emit(&mut w, s);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &s in symbols {
            assert_eq!(dec.decode(&mut r).unwrap(), s);
        }
    }

    #[test]
    fn dc_luma_roundtrip() {
        roundtrip(&dc_luma_spec(), &[0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 0, 5]);
    }

    #[test]
    fn dc_chroma_roundtrip() {
        roundtrip(&dc_chroma_spec(), &[11, 0, 3, 7, 1, 1, 0]);
    }

    #[test]
    fn ac_luma_roundtrip_all_symbols() {
        let spec = ac_luma_spec();
        let syms = spec.values.clone();
        roundtrip(&spec, &syms);
    }

    #[test]
    fn ac_chroma_roundtrip_all_symbols() {
        let spec = ac_chroma_spec();
        let syms = spec.values.clone();
        roundtrip(&spec, &syms);
    }

    #[test]
    fn spec_counts_match_values() {
        for spec in [dc_luma_spec(), dc_chroma_spec(), ac_luma_spec(), ac_chroma_spec()] {
            let total: usize = spec.counts.iter().map(|&c| c as usize).sum();
            assert_eq!(total, spec.values.len());
        }
    }

    #[test]
    fn canonical_prefix_free() {
        // no code is a prefix of another in the encoder table
        let enc = HuffEncoder::new(&ac_luma_spec());
        let spec = ac_luma_spec();
        for &a in &spec.values {
            for &b in &spec.values {
                if a == b {
                    continue;
                }
                let (la, lb) = (enc.code_len(a) as u32, enc.code_len(b) as u32);
                if la <= lb {
                    let ca = enc.code[a as usize] as u32;
                    let cb = enc.code[b as usize] as u32;
                    assert_ne!(ca, cb >> (lb - la), "{a:#x} prefix of {b:#x}");
                }
            }
        }
    }

    #[test]
    fn fast_and_slow_paths_agree() {
        // long AC codes exercise the slow path
        let spec = ac_luma_spec();
        let syms: Vec<u8> = spec.values.iter().rev().cloned().collect();
        roundtrip(&spec, &syms);
    }
}
