//! PJRT runtime: manifest-driven loading and execution of the AOT
//! artifacts (L2 graphs with embedded L1 Pallas kernels).
//!
//! `Engine` owns one PJRT CPU client and a lazily-populated executable
//! cache; `Session` wraps an engine with the model-level call surface
//! the coordinator uses (forward / train-step / convert), marshalling
//! `ParamSet`s and batches into artifact input lists.

mod engine;
pub mod manifest;
pub mod session;

pub use engine::{Engine, Value};
pub use manifest::{ArtifactSpec, DType, IoSpec, Manifest};
pub use session::{Session, TrainState};
