//! Streaming socket front end: the network edge of the native serving
//! pipeline.
//!
//! Until this module, the PR-2 pipeline (bounded admission, decode
//! pool, quant-table micro-batching, per-request deadlines) was only
//! reachable by in-process callers.  Here it gets a wire:
//!
//! * [`protocol`] — the length-prefixed binary frame format (versioned
//!   header, request id, optional deadline budget in µs, quality hint,
//!   JPEG payload; responses carry logits or a typed [`WireCode`]
//!   mirroring `ServeError` plus `WarmingUp` and `Protocol`).  Since
//!   the telemetry PR it also carries **stats frames**: a payload-less
//!   scrape request answered with the server's metrics registry
//!   rendered as Prometheus-style exposition text (see
//!   [`crate::telemetry`]); peers predating the extension answer the
//!   unknown kind with a typed `Protocol` error, never a hang.
//! * [`listener`] — [`SocketFrontend`]: a `std::net` acceptor plus
//!   connection worker pool (no async runtime) feeding any
//!   [`crate::serving::ServeBackend`] (one pipeline or a sharded
//!   coordinator) through completion sinks, with a fixed reply-pump
//!   pool streaming responses back **out of order** by request id, a
//!   per-connection token bucket (request cost in header byte 21,
//!   empty bucket answers [`WireCode::RateLimited`]), and a per-shard
//!   slow-start gate that answers [`WireCode::WarmingUp`] until the
//!   shard owning the request's quant table has served its warmup
//!   batches.
//! * [`client`] — the blocking [`Client`] library, reused by
//!   `repro serve bench --remote` and `examples/serve_requests.rs`.
//!
//! The load-bearing invariant carried across the network boundary: a
//! logit row read off the socket is **bit-identical** to an in-process
//! `Plan::run` under the same executor — enforced end to end by
//! `rust/tests/serving_socket.rs` at qualities 50/75/90.

pub mod client;
pub mod listener;
pub mod protocol;

pub use client::{Client, ClientError, RemoteResponse, Reply};
pub use listener::{FrontendConfig, SocketFrontend};
pub use protocol::{ProtocolError, WireCode};
