//! Pure-rust JPEG-transform-domain network ops (paper §4).
//!
//! Mirrors `python/compile/layers.py`: the same math that the AOT
//! artifacts execute, implemented natively so the rust side has an
//! oracle, a CPU baseline, and a fast harness for the per-block
//! experiments (Fig 4a runs millions of blocks through [`relu`]).
//!
//! ## Invariants
//!
//! * **Layout** — coefficient tensors are `(N, C, Bh, Bw, 64)`, zigzag
//!   order, divided by the quantization vector (the paper's domain).
//!   Sparse activations ([`crate::tensor::SparseBlocks`]) store the
//!   same blocks in the same order as runs of ascending
//!   `(zigzag index, value)` pairs.
//! * **Two interchangeable activation forms** — every layer op exists
//!   over dense tensors and over sparse runs ([`conv`], [`batchnorm`],
//!   [`relu`]); the sparse forms perform the identical float
//!   operations on the identical nonzeros, so the sparse-resident
//!   execution strategy ([`plan::SparseResident`]) is bit-identical to
//!   the dense-boundary one ([`plan::SparseKernel`]).
//! * **One topology, many strategies** — the network is data: the
//!   single ResNet graph ([`network::RESNET_PLAN`]) runs under any
//!   [`plan::Executor`]; execution modes differ only in kernels and
//!   activation representation, never in layer sequencing.
//! * **Band masks are zigzag prefixes** — the ASM/APX phi mask keeps
//!   the lowest spatial-frequency bands, which are contiguous leading
//!   zigzag indices ([`crate::jpeg::zigzag::band_cutoff`]); on runs,
//!   masking is a truncation.

pub mod batchnorm;
pub mod conv;
pub mod harmonic;
pub mod network;
pub mod plan;
pub mod relu;

use once_cell::sync::Lazy;

use crate::jpeg::dct::DCT2D;
use crate::jpeg::zigzag::ZIGZAG;
use crate::tensor::Tensor;

/// (64, 64) zigzag-ordered orthonormal DCT: y_zz = ZA @ x_flat.
pub static ZA: Lazy<Vec<f32>> = Lazy::new(|| {
    let a = &*DCT2D;
    let mut za = vec![0.0f32; 64 * 64];
    for k in 0..64 {
        za[k * 64..(k + 1) * 64]
            .copy_from_slice(&a[ZIGZAG[k] * 64..(ZIGZAG[k] + 1) * 64]);
    }
    za
});

/// Row-vector decode matrix: x_flat = f_zz @ dec (dequant + unzigzag +
/// IDCT);  dec[k][p] = ZA[k][p] * q[k].
pub fn dec_matrix(qvec: &[f32; 64]) -> Tensor {
    let za = &*ZA;
    let mut m = vec![0.0f32; 64 * 64];
    for k in 0..64 {
        for p in 0..64 {
            m[k * 64 + p] = za[k * 64 + p] * qvec[k];
        }
    }
    Tensor::from_vec(&[64, 64], m)
}

/// Row-vector encode matrix: f_zz = x_flat @ enc;  enc[p][k] = ZA[k][p]/q[k].
pub fn enc_matrix(qvec: &[f32; 64]) -> Tensor {
    let za = &*ZA;
    let mut m = vec![0.0f32; 64 * 64];
    for p in 0..64 {
        for k in 0..64 {
            m[p * 64 + k] = za[k * 64 + p] / qvec[k];
        }
    }
    Tensor::from_vec(&[64, 64], m)
}

/// Image (N, C, H, W) -> domain coefficients (N, C, H/8, W/8, 64).
pub fn encode_tensor(x: &Tensor, qvec: &[f32; 64]) -> Tensor {
    let (n, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    assert!(h % 8 == 0 && w % 8 == 0);
    let (bh, bw) = (h / 8, w / 8);
    let za = &*ZA;
    let mut out = vec![0.0f32; n * c * bh * bw * 64];
    let xd = x.data();
    let mut block = [0.0f32; 64];
    for b in 0..n {
        for ci in 0..c {
            let plane = (b * c + ci) * h * w;
            for by in 0..bh {
                for bx in 0..bw {
                    for y in 0..8 {
                        let row = plane + (by * 8 + y) * w + bx * 8;
                        block[y * 8..y * 8 + 8].copy_from_slice(&xd[row..row + 8]);
                    }
                    let off = ((((b * c + ci) * bh) + by) * bw + bx) * 64;
                    for k in 0..64 {
                        let zarow = &za[k * 64..(k + 1) * 64];
                        let dot: f32 =
                            zarow.iter().zip(&block).map(|(a, x)| a * x).sum();
                        out[off + k] = dot / qvec[k];
                    }
                }
            }
        }
    }
    Tensor::from_vec(&[n, c, bh, bw, 64], out)
}

/// Domain coefficients (N, C, Bh, Bw, 64) -> image (N, C, 8Bh, 8Bw).
pub fn decode_tensor(f: &Tensor, qvec: &[f32; 64]) -> Tensor {
    let s = f.shape();
    let (n, c, bh, bw) = (s[0], s[1], s[2], s[3]);
    let (h, w) = (bh * 8, bw * 8);
    let za = &*ZA;
    let mut out = vec![0.0f32; n * c * h * w];
    let fd = f.data();
    for b in 0..n {
        for ci in 0..c {
            let plane = (b * c + ci) * h * w;
            for by in 0..bh {
                for bx in 0..bw {
                    let off = ((((b * c + ci) * bh) + by) * bw + bx) * 64;
                    let mut block = [0.0f32; 64];
                    for k in 0..64 {
                        let v = fd[off + k] * qvec[k];
                        if v == 0.0 {
                            continue;
                        }
                        let zarow = &za[k * 64..(k + 1) * 64];
                        for (o, &a) in block.iter_mut().zip(zarow) {
                            *o += v * a;
                        }
                    }
                    for y in 0..8 {
                        let row = plane + (by * 8 + y) * w + bx * 8;
                        out[row..row + 8].copy_from_slice(&block[y * 8..y * 8 + 8]);
                    }
                }
            }
        }
    }
    Tensor::from_vec(&[n, c, h, w], out)
}

/// Flat all-ones quantization vector (the "lossless" setting).
pub fn qvec_flat() -> [f32; 64] {
    [1.0; 64]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_image(seed: u64, n: usize, c: usize, h: usize, w: usize) -> Tensor {
        let mut rng = Rng::new(seed);
        Tensor::from_vec(
            &[n, c, h, w],
            (0..n * c * h * w).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
        )
    }

    #[test]
    fn dec_enc_are_inverse() {
        for q in [qvec_flat(), crate::jpeg::QuantTable::luma(50).as_f32()] {
            let d = dec_matrix(&q);
            let e = enc_matrix(&q);
            let prod = crate::tensor::matmul(&d, &e);
            for i in 0..64 {
                for j in 0..64 {
                    let expect = if i == j { 1.0 } else { 0.0 };
                    assert!((prod.at(&[i, j]) - expect).abs() < 1e-4);
                }
            }
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let x = rand_image(1, 2, 3, 16, 24);
        let q = qvec_flat();
        let f = encode_tensor(&x, &q);
        assert_eq!(f.shape(), &[2, 3, 2, 3, 64]);
        let back = decode_tensor(&f, &q);
        assert!(x.max_abs_diff(&back) < 1e-4);
    }

    #[test]
    fn roundtrip_lossy_table() {
        let x = rand_image(2, 1, 1, 32, 32);
        let q = crate::jpeg::QuantTable::luma(75).as_f32();
        let f = encode_tensor(&x, &q);
        let back = decode_tensor(&f, &q);
        assert!(x.max_abs_diff(&back) < 1e-3);
    }

    #[test]
    fn linearity() {
        // paper eq. 25
        let a = rand_image(3, 1, 1, 16, 16);
        let b = rand_image(4, 1, 1, 16, 16);
        let q = qvec_flat();
        let lhs = encode_tensor(&a.add(&b), &q);
        let rhs = encode_tensor(&a, &q).add(&encode_tensor(&b, &q));
        assert!(lhs.max_abs_diff(&rhs) < 1e-4);
    }

    #[test]
    fn dc_is_scaled_mean() {
        let x = rand_image(5, 1, 1, 8, 8);
        let f = encode_tensor(&x, &qvec_flat());
        let mean = x.mean();
        assert!((f.at(&[0, 0, 0, 0, 0]) - 8.0 * mean).abs() < 1e-4);
    }

    #[test]
    fn matches_codec_dct() {
        // encode_tensor and the codec's forward DCT agree on one block
        let x = rand_image(6, 1, 1, 8, 8);
        let mut block = [0.0f32; 64];
        block.copy_from_slice(x.data());
        let f = crate::jpeg::dct::forward(&block);
        let zz = crate::jpeg::zigzag::to_zigzag(&f);
        let enc = encode_tensor(&x, &qvec_flat());
        for k in 0..64 {
            assert!((enc.data()[k] - zz[k]).abs() < 1e-4, "k={k}");
        }
    }
}
