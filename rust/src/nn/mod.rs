//! Pure-rust spatial reference network (the paper's Figure-3 classifier).
//!
//! This is the oracle and CPU baseline: eval-mode forward pass matching
//! `python/compile/model.py::spatial_forward` bit-for-bit up to float
//! associativity.  Training runs through the AOT artifacts; this module
//! exists so rust-side tests and experiments can verify numerics without
//! Python or PJRT in the loop.

use crate::params::{ModelConfig, ParamSet};
use crate::tensor::{conv2d, matmul, Tensor};

pub const BN_EPS: f32 = 1e-5;

/// Eval-mode batch norm over (N, C, H, W) using running statistics.
pub fn batch_norm_eval(x: &Tensor, gamma: &Tensor, beta: &Tensor, rmean: &Tensor, rvar: &Tensor) -> Tensor {
    let (n, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let mut out = vec![0.0f32; x.len()];
    let xd = x.data();
    for ci in 0..c {
        let inv = gamma.data()[ci] / (rvar.data()[ci] + BN_EPS).sqrt();
        let shift = beta.data()[ci] - rmean.data()[ci] * inv;
        for b in 0..n {
            let off = (b * c + ci) * h * w;
            for i in 0..h * w {
                out[off + i] = xd[off + i] * inv + shift;
            }
        }
    }
    Tensor::from_vec(x.shape(), out)
}

/// Global average pool (N, C, H, W) -> (N, C).
pub fn global_avg_pool(x: &Tensor) -> Tensor {
    let (n, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let hw = (h * w) as f32;
    let mut out = vec![0.0f32; n * c];
    for b in 0..n {
        for ci in 0..c {
            let off = (b * c + ci) * h * w;
            out[b * c + ci] = x.data()[off..off + h * w].iter().sum::<f32>() / hw;
        }
    }
    Tensor::from_vec(&[n, c], out)
}

/// x @ w + b with x (N, D), w (D, K), b (K).
pub fn linear(x: &Tensor, w: &Tensor, b: &Tensor) -> Tensor {
    let mut out = matmul(x, w);
    let k = w.shape()[1];
    for row in out.data_mut().chunks_mut(k) {
        for (o, &bb) in row.iter_mut().zip(b.data()) {
            *o += bb;
        }
    }
    out
}

fn bn(p: &ParamSet, prefix: &str, x: &Tensor) -> Tensor {
    batch_norm_eval(
        x,
        p.get(&format!("{prefix}.gamma")),
        p.get(&format!("{prefix}.beta")),
        p.get(&format!("{prefix}.rmean")),
        p.get(&format!("{prefix}.rvar")),
    )
}

fn res_block(p: &ParamSet, prefix: &str, x: &Tensor, stride: usize) -> Tensor {
    let mut y = conv2d(x, p.get(&format!("{prefix}.conv1.w")), stride);
    y = bn(p, &format!("{prefix}.bn1"), &y).relu();
    y = conv2d(&y, p.get(&format!("{prefix}.conv2.w")), 1);
    y = bn(p, &format!("{prefix}.bn2"), &y);
    let sc = if stride != 1 {
        let s = conv2d(x, p.get(&format!("{prefix}.proj.w")), stride);
        bn(p, &format!("{prefix}.projbn"), &s)
    } else {
        x.clone()
    };
    y.add(&sc).relu()
}

/// Eval forward: (N, C, 32, 32) pixels in [0,1] -> (N, classes) logits.
pub fn spatial_forward(cfg: &ModelConfig, p: &ParamSet, x: &Tensor) -> Tensor {
    assert_eq!(x.shape()[1], cfg.in_channels);
    let mut y = conv2d(x, p.get("stem.conv.w"), 1);
    y = bn(p, "stem.bn", &y).relu();
    y = res_block(p, "block1", &y, 1);
    y = res_block(p, "block2", &y, 2);
    y = res_block(p, "block3", &y, 2);
    let g = global_avg_pool(&y);
    linear(&g, p.get("fc.w"), p.get("fc.b"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn cfg() -> ModelConfig {
        ModelConfig::preset("mnist").unwrap()
    }

    fn rand_input(cfg: &ModelConfig, n: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let len = n * cfg.in_channels * 32 * 32;
        Tensor::from_vec(
            &[n, cfg.in_channels, 32, 32],
            (0..len).map(|_| rng.uniform()).collect(),
        )
    }

    #[test]
    fn forward_shapes() {
        let c = cfg();
        let p = ParamSet::init(&c, 0);
        let x = rand_input(&c, 2, 1);
        let logits = spatial_forward(&c, &p, &x);
        assert_eq!(logits.shape(), &[2, 10]);
        assert!(logits.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn forward_deterministic() {
        let c = cfg();
        let p = ParamSet::init(&c, 0);
        let x = rand_input(&c, 2, 1);
        assert_eq!(spatial_forward(&c, &p, &x), spatial_forward(&c, &p, &x));
    }

    #[test]
    fn batchnorm_eval_formula() {
        let x = Tensor::from_vec(&[1, 1, 1, 2], vec![2.0, 4.0]);
        let g = Tensor::from_vec(&[1], vec![2.0]);
        let b = Tensor::from_vec(&[1], vec![1.0]);
        let rm = Tensor::from_vec(&[1], vec![3.0]);
        let rv = Tensor::from_vec(&[1], vec![4.0]);
        let y = batch_norm_eval(&x, &g, &b, &rm, &rv);
        // (x - 3) * 2 / sqrt(4 + eps) + 1
        assert!((y.data()[0] - 0.0).abs() < 1e-3);
        assert!((y.data()[1] - 2.0).abs() < 1e-3);
    }

    #[test]
    fn gap_means() {
        let x = Tensor::from_vec(&[1, 2, 1, 2], vec![1.0, 3.0, 10.0, 20.0]);
        let g = global_avg_pool(&x);
        assert_eq!(g.data(), &[2.0, 15.0]);
    }

    #[test]
    fn linear_bias() {
        let x = Tensor::from_vec(&[1, 2], vec![1.0, 1.0]);
        let w = Tensor::from_vec(&[2, 3], vec![1., 0., 0., 0., 1., 0.]);
        let b = Tensor::from_vec(&[3], vec![0.5, 0.5, 0.5]);
        assert_eq!(linear(&x, &w, &b).data(), &[1.5, 1.5, 0.5]);
    }

    #[test]
    fn cifar_config_forward() {
        let c = ModelConfig::preset("cifar100").unwrap();
        let p = ParamSet::init(&c, 3);
        let x = rand_input(&c, 1, 4);
        let logits = spatial_forward(&c, &p, &x);
        assert_eq!(logits.shape(), &[1, 100]);
    }
}
