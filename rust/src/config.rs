//! Config system: a TOML-subset parser (std-only) + typed run configs.
//!
//! Supports the subset real deployments of this system need: `[section]`
//! headers, `key = value` with strings, integers, floats, booleans and
//! `#` comments.  CLI flags override file values (see `main.rs`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// A parsed config value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// section -> key -> value
#[derive(Clone, Debug, Default)]
pub struct Config {
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Config {
    pub fn parse(text: &str) -> anyhow::Result<Config> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = k.trim().to_string();
            let val = Self::parse_value(v.trim())
                .ok_or_else(|| anyhow::anyhow!("line {}: bad value {:?}", lineno + 1, v.trim()))?;
            cfg.sections.entry(section.clone()).or_default().insert(key, val);
        }
        Ok(cfg)
    }

    fn parse_value(s: &str) -> Option<Value> {
        if let Some(q) = s.strip_prefix('"').and_then(|x| x.strip_suffix('"')) {
            return Some(Value::Str(q.to_string()));
        }
        match s {
            "true" => return Some(Value::Bool(true)),
            "false" => return Some(Value::Bool(false)),
            _ => {}
        }
        if let Ok(i) = s.parse::<i64>() {
            return Some(Value::Int(i));
        }
        if let Ok(f) = s.parse::<f64>() {
            return Some(Value::Float(f));
        }
        None
    }

    pub fn load(path: &Path) -> anyhow::Result<Config> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> usize {
        self.get(section, key)
            .and_then(|v| v.as_i64())
            .map(|i| i as usize)
            .unwrap_or(default)
    }

    pub fn f32_or(&self, section: &str, key: &str, default: f32) -> f32 {
        self.get(section, key)
            .and_then(|v| v.as_f64())
            .map(|f| f as f32)
            .unwrap_or(default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

/// Resolve a worker-thread request: `0` means "auto" (all available
/// hardware parallelism), anything else is taken literally.  Used by
/// the sparse exploded-conv execution paths.
pub fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Shared run settings resolved from config + CLI.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub artifacts_dir: PathBuf,
    pub dataset: String,
    pub quality: u8,
    pub seed: u64,
    /// Worker threads for the sparse execution paths (`0` = auto).
    pub threads: usize,
    /// Post-ReLU magnitude prune of the sparse-resident executor
    /// (`0.0` = exact; the paper's "little to no penalty" knob,
    /// measured by `repro exp prune`).
    pub prune_epsilon: f32,
    /// Inner-loop axpy kernel of the sparse conv paths:
    /// "scalar4" | "scalar8" | "simd" | "auto" (parsed into
    /// `jpeg_domain::conv::AxpyKernel` at use sites; "auto" picks SIMD
    /// when the CPU supports it).  Measured by `repro exp axpy`.
    pub axpy: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            dataset: "mnist".to_string(),
            quality: 95,
            seed: 0,
            threads: 0,
            prune_epsilon: 0.0,
            axpy: "auto".to_string(),
        }
    }
}

impl RunConfig {
    pub fn from_config(cfg: &Config) -> RunConfig {
        let d = RunConfig::default();
        RunConfig {
            artifacts_dir: PathBuf::from(cfg.str_or(
                "run",
                "artifacts_dir",
                d.artifacts_dir.to_str().unwrap(),
            )),
            dataset: cfg.str_or("run", "dataset", &d.dataset),
            quality: cfg.usize_or("run", "quality", d.quality as usize) as u8,
            seed: cfg.usize_or("run", "seed", d.seed as usize) as u64,
            threads: cfg.usize_or("run", "threads", d.threads),
            prune_epsilon: cfg.f32_or("run", "prune_epsilon", d.prune_epsilon),
            axpy: cfg.str_or("run", "axpy", &d.axpy),
        }
    }

    /// The effective worker-thread count for this run.
    pub fn effective_threads(&self) -> usize {
        resolve_threads(self.threads)
    }
}

/// `[serve]` settings resolved from config (CLI flags override in
/// `main.rs`).  Mirrors `serving::PipelineConfig` plus the engine
/// switch.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// "native" or "pjrt".
    pub engine: String,
    /// Native engine kernel: "sparse-resident" (activations stay in
    /// `SparseBlocks` form between layers; the default), "sparse"
    /// (dense-boundary baseline) or "dense" (Algorithm-1 baseline).
    pub mode: String,
    pub decode_workers: usize,
    pub compute_workers: usize,
    pub queue_capacity: usize,
    pub decoded_capacity: usize,
    pub max_batch: usize,
    pub max_wait_ms: usize,
    /// Socket front-end bind address (`--listen` overrides); empty =
    /// in-process serving only, no listener.
    pub listen_addr: String,
    /// Slow-start gate: compute batches the pipeline must serve before
    /// socket traffic is admitted (rejected with the typed `WarmingUp`
    /// wire code until then); `0` disables the gate.
    pub warmup_batches: usize,
    /// Request tracing: emit per-stage JSONL spans for every Nth
    /// admitted request (`--trace-sample` overrides); `0` disables
    /// tracing entirely (no sampling cost on the hot path).
    pub trace_sample: usize,
    /// Pipeline replicas behind consistent hashing on the quant table
    /// (`--shards` overrides); `1` = the single unsharded pipeline.
    pub shards: usize,
    /// Per-connection token-bucket refill rate in tokens/second
    /// (`--rate-limit` overrides); `0` disables rate limiting.
    pub rate_limit: usize,
    /// Token-bucket burst capacity (`--rate-burst` overrides); `0`
    /// defaults to `rate_limit`.
    pub rate_burst: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            engine: "native".to_string(),
            mode: "sparse-resident".to_string(),
            decode_workers: 2,
            compute_workers: 1,
            queue_capacity: 256,
            decoded_capacity: 64,
            max_batch: 8,
            max_wait_ms: 5,
            listen_addr: String::new(),
            warmup_batches: 0,
            trace_sample: 0,
            shards: 1,
            rate_limit: 0,
            rate_burst: 0,
        }
    }
}

impl ServeConfig {
    pub fn from_config(cfg: &Config) -> ServeConfig {
        let d = ServeConfig::default();
        ServeConfig {
            engine: cfg.str_or("serve", "engine", &d.engine),
            mode: cfg.str_or("serve", "mode", &d.mode),
            decode_workers: cfg.usize_or("serve", "decode_workers", d.decode_workers),
            compute_workers: cfg.usize_or("serve", "compute_workers", d.compute_workers),
            queue_capacity: cfg.usize_or("serve", "queue_capacity", d.queue_capacity),
            decoded_capacity: cfg.usize_or("serve", "decoded_capacity", d.decoded_capacity),
            max_batch: cfg.usize_or("serve", "max_batch", d.max_batch),
            max_wait_ms: cfg.usize_or("serve", "max_wait_ms", d.max_wait_ms),
            listen_addr: cfg.str_or("serve", "listen_addr", &d.listen_addr),
            warmup_batches: cfg.usize_or("serve", "warmup_batches", d.warmup_batches),
            trace_sample: cfg.usize_or("serve", "trace_sample", d.trace_sample),
            shards: cfg.usize_or("serve", "shards", d.shards),
            rate_limit: cfg.usize_or("serve", "rate_limit", d.rate_limit),
            rate_burst: cfg.usize_or("serve", "rate_burst", d.rate_burst),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# a comment
[run]
dataset = "cifar10"
quality = 85
seed = 3

[train]
steps = 200
lr = 0.05
verbose = true
"#;

    #[test]
    fn parse_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str_or("run", "dataset", "x"), "cifar10");
        assert_eq!(c.usize_or("run", "quality", 0), 85);
        assert_eq!(c.usize_or("train", "steps", 0), 200);
        assert!((c.f32_or("train", "lr", 0.0) - 0.05).abs() < 1e-9);
        assert!(c.bool_or("train", "verbose", false));
    }

    #[test]
    fn defaults_for_missing() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.usize_or("nope", "k", 7), 7);
        assert_eq!(c.str_or("run", "nope", "d"), "d");
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let c = Config::parse("# only a comment\n\n[a]\nx = 1 # trailing\n").unwrap();
        assert_eq!(c.usize_or("a", "x", 0), 1);
    }

    #[test]
    fn bad_lines_error() {
        assert!(Config::parse("[a]\nnot a kv\n").is_err());
        assert!(Config::parse("[a]\nx = @@@\n").is_err());
    }

    #[test]
    fn run_config_from() {
        let c = Config::parse(SAMPLE).unwrap();
        let r = RunConfig::from_config(&c);
        assert_eq!(r.dataset, "cifar10");
        assert_eq!(r.quality, 85);
        assert_eq!(r.seed, 3);
        assert_eq!(r.threads, 0, "threads defaults to auto");
        assert_eq!(r.prune_epsilon, 0.0, "prune defaults to exact");
        assert_eq!(r.axpy, "auto", "axpy kernel defaults to auto");
        let c2 = Config::parse("[run]\nprune_epsilon = 0.001\naxpy = \"scalar8\"\n").unwrap();
        let r2 = RunConfig::from_config(&c2);
        assert!((r2.prune_epsilon - 0.001).abs() < 1e-9);
        assert_eq!(r2.axpy, "scalar8");
        assert!(r2.axpy.parse::<crate::jpeg_domain::conv::AxpyKernel>().is_ok());
    }

    #[test]
    fn serve_config_defaults_and_overrides() {
        let d = ServeConfig::from_config(&Config::default());
        assert_eq!(d.engine, "native");
        assert_eq!(d.mode, "sparse-resident");
        assert_eq!(d.queue_capacity, 256);
        let c = Config::parse(
            "[serve]\nengine = \"pjrt\"\nqueue_capacity = 8\nmax_batch = 2\n",
        )
        .unwrap();
        let s = ServeConfig::from_config(&c);
        assert_eq!(s.engine, "pjrt");
        assert_eq!(s.queue_capacity, 8);
        assert_eq!(s.max_batch, 2);
        assert_eq!(s.decode_workers, 2, "untouched keys keep defaults");
        assert_eq!(s.listen_addr, "", "no listener unless configured");
        assert_eq!(s.warmup_batches, 0, "slow start off by default");
        assert_eq!(s.trace_sample, 0, "tracing off by default");
        let c = Config::parse(
            "[serve]\nlisten_addr = \"127.0.0.1:7878\"\nwarmup_batches = 3\ntrace_sample = 10\n",
        )
        .unwrap();
        let s = ServeConfig::from_config(&c);
        assert_eq!(s.listen_addr, "127.0.0.1:7878");
        assert_eq!(s.warmup_batches, 3);
        assert_eq!(s.trace_sample, 10);
        assert_eq!(s.shards, 1, "unsharded by default");
        assert_eq!(s.rate_limit, 0, "rate limiting off by default");
        let c = Config::parse("[serve]\nshards = 4\nrate_limit = 100\nrate_burst = 200\n")
            .unwrap();
        let s = ServeConfig::from_config(&c);
        assert_eq!(s.shards, 4);
        assert_eq!(s.rate_limit, 100);
        assert_eq!(s.rate_burst, 200);
    }

    #[test]
    fn threads_knob() {
        let c = Config::parse("[run]\nthreads = 6\n").unwrap();
        let r = RunConfig::from_config(&c);
        assert_eq!(r.threads, 6);
        assert_eq!(r.effective_threads(), 6);
        assert_eq!(resolve_threads(3), 3);
        assert!(resolve_threads(0) >= 1, "auto resolves to >= 1");
    }
}
