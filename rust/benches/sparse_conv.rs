//! Bench: the sparsity-aware exploded-conv engine — dense Algorithm-1
//! gather+matmul vs the gather-free sparse kernel vs the threaded
//! sparse kernel, on a real entropy-decoded quality-50 batch.
//! Pure rust: runs without PJRT artifacts.
//! `cargo bench --bench sparse_conv`
//! Env: SC_QUALITY (50), SC_BATCH (40), SC_COUT (16), SC_THREADS (0 =
//! auto), SC_ITERS (5).

use jpegdomain::bench_harness as bh;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let r = bh::sparse_conv_ablation(
        env_usize("SC_QUALITY", 50) as u8,
        env_usize("SC_BATCH", 40),
        env_usize("SC_COUT", 16),
        env_usize("SC_THREADS", 0),
        env_usize("SC_ITERS", 5),
    );
    bh::throughput::print_sparse_conv(&r);
    assert!(
        r.max_abs_diff_vs_dcc < 1e-3,
        "sparse kernel drifted from the DCC oracle: {}",
        r.max_abs_diff_vs_dcc
    );
    assert!(
        r.sparse_blocks_per_sec > r.dense_blocks_per_sec,
        "sparse path must beat the dense path on quality-50 input \
         ({:.0} !> {:.0} blocks/s)",
        r.sparse_blocks_per_sec,
        r.dense_blocks_per_sec
    );
    println!(
        "\nsparse_conv bench OK (sparse {:.2}x dense, {:.2}x thread scaling at {} threads)",
        r.sparse_speedup, r.thread_scaling, r.threads
    );
}
