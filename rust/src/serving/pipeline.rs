//! The staged native pipeline: admission -> decode pool -> compute pool.
//!
//! See the module doc in [`crate::serving`] for the topology and where
//! backpressure applies.  Replies travel over per-request oneshot-style
//! channels as `anyhow::Result<InferResponse>`; typed failures are
//! [`ServeError`]s recoverable via `downcast_ref`.
//!
//! ## Telemetry
//!
//! Every pipeline owns one telemetry [`Registry`] holding all of its
//! instruments — per-stage metrics, the coordinator-compatible
//! aggregate, live queue-depth gauges (`jd_queue_depth{queue=...}`),
//! and per-`LayerOp` wall-time histograms recorded on every forward.
//! The socket front end renders it for `Stats` scrapes
//! ([`NativePipeline::registry`]).  With a [`Tracer`] attached
//! ([`NativePipeline::start_traced`]), every sampled request emits one
//! JSONL span per stage: `admission`, `decode`, `handoff`,
//! `batch-assembly`, `compute` here, and `socket-write` in the
//! listener.  Tracing is wall-clock bookkeeping only — logits stay
//! bit-identical with tracing on or off.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::coordinator::metrics::Metrics;
use crate::coordinator::server::InferResponse;
use crate::jpeg::codec;
use crate::jpeg_domain::plan::Tee;
use crate::telemetry::{Counter, Registry, Tracer};
use crate::tensor::SparseBlocks;

use super::engine::NativeEngine;
use super::error::ServeError;
use super::metrics::{OpRecorder, PipelineMetrics, QualityTag};
use super::queue::{bounded_with_gauge, BoundedReceiver, BoundedSender, SendRejected};
use super::shard::batcher::{shared_batcher, BatchReceiver, BatchSender};

/// Pipeline sizing.  Capacities bound every queue in the system; worker
/// counts size the two pools.
#[derive(Clone, Copy, Debug)]
pub struct PipelineConfig {
    /// Entropy-decode workers (stage 1).
    pub decode_workers: usize,
    /// Forward-pass workers (stage 2).
    pub compute_workers: usize,
    /// Admission queue capacity; beyond it `try_submit` rejects.
    pub queue_capacity: usize,
    /// Decoded-job queue capacity (decode blocks when full).
    pub decoded_capacity: usize,
    /// Compute micro-batch ceiling (requests coalesced per forward).
    pub max_batch: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            decode_workers: 2,
            compute_workers: 1,
            queue_capacity: 256,
            decoded_capacity: 64,
            max_batch: 8,
        }
    }
}

/// A completion sink: the reply-pump path of the socket front end.
/// Instead of parking a waiter thread on a channel, the pipeline calls
/// the closure from whichever worker finishes the request; the closure
/// enqueues the encoded response onto the frontend's completion queue.
///
/// Delivery is guaranteed: a sink dropped unconsumed (a worker died
/// mid-request) fires with [`ServeError::WorkerLost`], preserving the
/// channel path's "receiver sees an error, never silence" contract.
pub struct ReplySink(Option<Box<dyn FnOnce(anyhow::Result<InferResponse>) + Send>>);

impl ReplySink {
    pub fn new(f: impl FnOnce(anyhow::Result<InferResponse>) + Send + 'static) -> ReplySink {
        ReplySink(Some(Box::new(f)))
    }

    fn deliver(mut self, result: anyhow::Result<InferResponse>) {
        if let Some(f) = self.0.take() {
            f(result);
        }
    }

    /// Disarm without firing — for rejected submissions, where the
    /// caller keeps responsibility for the reply.
    fn defuse(&mut self) {
        self.0.take();
    }
}

impl Drop for ReplySink {
    fn drop(&mut self) {
        if let Some(f) = self.0.take() {
            f(Err(anyhow::Error::new(ServeError::WorkerLost)));
        }
    }
}

/// How a finished request reaches its caller: the in-process channel
/// (blocking `recv`) or a frontend completion sink.
enum Reply {
    Channel(Sender<anyhow::Result<InferResponse>>),
    Sink(ReplySink),
}

impl Reply {
    fn deliver(self, result: anyhow::Result<InferResponse>) {
        match self {
            // a gone receiver is fine: the caller abandoned the request
            Reply::Channel(tx) => drop(tx.send(result)),
            Reply::Sink(s) => s.deliver(result),
        }
    }

    fn defuse(&mut self) {
        if let Reply::Sink(s) = self {
            s.defuse();
        }
    }
}

/// The decode→compute staging key: same quant table (bit patterns) +
/// same block geometry ⇒ batchable into one forward.
type BatchKey = ([u32; 64], (usize, usize, usize, usize));

/// One admission request: raw JPEG bytes plus an optional absolute
/// deadline.  A request whose deadline passes before its forward pass
/// runs is dropped with [`ServeError::DeadlineExceeded`] — at
/// admission, at decode pickup, or at compute batch assembly — so an
/// overloaded server never burns decode or kernel time on replies the
/// client has already abandoned.
pub struct ServeRequest {
    /// Entropy-coded JPEG bytes.
    pub bytes: Vec<u8>,
    /// Latest instant at which starting compute is still useful.
    pub deadline: Option<Instant>,
    /// Caller-supplied id carried into trace spans (the socket front
    /// end passes the wire request id).  0 = unassigned; the pipeline
    /// assigns an internal id to sampled requests so spans correlate.
    pub request_id: u64,
}

impl ServeRequest {
    /// A request with no deadline.
    pub fn new(bytes: Vec<u8>) -> ServeRequest {
        ServeRequest { bytes, deadline: None, request_id: 0 }
    }

    /// Attach an absolute deadline.
    pub fn with_deadline(mut self, deadline: Instant) -> ServeRequest {
        self.deadline = Some(deadline);
        self
    }

    /// Attach an external request id (trace-span correlation).
    pub fn with_request_id(mut self, id: u64) -> ServeRequest {
        self.request_id = id;
        self
    }
}

fn expired(deadline: Option<Instant>) -> bool {
    deadline.map_or(false, |d| Instant::now() >= d)
}

struct Job {
    bytes: Vec<u8>,
    deadline: Option<Instant>,
    submitted: Instant,
    request_id: u64,
    traced: bool,
    reply: Reply,
}

struct DecodedJob {
    /// Single-image sparse input (N = 1).
    f0: SparseBlocks,
    qvec: [f32; 64],
    tag: QualityTag,
    deadline: Option<Instant>,
    submitted: Instant,
    decoded_at: Instant,
    /// Just before the handoff send; batch-assembly spans start here.
    enqueued_at: Instant,
    request_id: u64,
    traced: bool,
    reply: Reply,
}

/// A running native pipeline.
pub struct NativePipeline {
    admit: Option<BoundedSender<Job>>,
    decode_handles: Vec<JoinHandle<()>>,
    compute_handles: Vec<JoinHandle<()>>,
    /// Per-stage metrics (latency, queue depth, per-quality traffic).
    pub metrics: Arc<PipelineMetrics>,
    /// Coordinator-compatible aggregate (requests/batches/latency), so
    /// the `Server` facade exposes one metrics surface for both engines.
    aggregate: Arc<Metrics>,
    /// The registry every instrument above lives in (scrape source).
    registry: Arc<Registry>,
    tracer: Option<Arc<Tracer>>,
    /// Internal ids for requests submitted without one (high bit set to
    /// keep them visually distinct from typical wire ids).
    seq: AtomicU64,
    engine: Arc<NativeEngine>,
    /// Batches served by THIS pipeline.  The registry aggregate is
    /// shared across shard replicas, so per-shard warmup needs a
    /// local counter (equal to the aggregate when unsharded).
    local_batches: Arc<Counter>,
}

impl NativePipeline {
    pub fn start(engine: NativeEngine, cfg: PipelineConfig) -> NativePipeline {
        Self::start_traced(engine, cfg, None)
    }

    /// [`NativePipeline::start`] with an optional span tracer attached
    /// to the whole pipeline (`--trace-sample`).
    pub fn start_traced(
        engine: NativeEngine,
        cfg: PipelineConfig,
        tracer: Option<Arc<Tracer>>,
    ) -> NativePipeline {
        Self::start_in(engine, cfg, tracer, Arc::new(Registry::new()), None)
    }

    /// Start as shard replica `shard` of a [`super::shard::ShardedCoordinator`]:
    /// instruments register in the coordinator's shared `registry`
    /// (aggregate families sum across replicas) and the queue-depth /
    /// batch-size families carry a `shard` label.
    pub fn start_sharded(
        engine: NativeEngine,
        cfg: PipelineConfig,
        tracer: Option<Arc<Tracer>>,
        registry: Arc<Registry>,
        shard: usize,
    ) -> NativePipeline {
        Self::start_in(engine, cfg, tracer, registry, Some(shard))
    }

    fn start_in(
        engine: NativeEngine,
        cfg: PipelineConfig,
        tracer: Option<Arc<Tracer>>,
        registry: Arc<Registry>,
        shard: Option<usize>,
    ) -> NativePipeline {
        let engine = Arc::new(engine);
        let metrics = Arc::new(PipelineMetrics::register(&registry));
        let aggregate = Arc::new(Metrics::register(&registry));
        // unsharded pipelines keep the PR-7 `jd_queue_depth{queue=...}`
        // families; shard replicas get per-shard families instead so
        // one scrape shows every replica's backlog side by side
        let (admit_gauge, staged_gauge, batch_hist) = match shard {
            None => (
                registry.gauge(
                    "jd_queue_depth",
                    "live items in a pipeline queue",
                    &[("queue", "admission")],
                ),
                registry.gauge(
                    "jd_queue_depth",
                    "live items in a pipeline queue",
                    &[("queue", "decoded")],
                ),
                None,
            ),
            Some(i) => {
                let label = i.to_string();
                (
                    registry.gauge(
                        "jd_shard_queue_depth",
                        "live items in a shard replica's queue",
                        &[("queue", "admission"), ("shard", label.as_str())],
                    ),
                    registry.gauge(
                        "jd_shard_queue_depth",
                        "live items in a shard replica's queue",
                        &[("queue", "staged"), ("shard", label.as_str())],
                    ),
                    Some(registry.histogram(
                        "jd_shard_batch_size",
                        "images per compute micro-batch (size rides the µs axis)",
                        &[("shard", label.as_str())],
                    )),
                )
            }
        };
        let (admit_tx, admit_rx) = bounded_with_gauge::<Job>(cfg.queue_capacity.max(1), admit_gauge);
        // the shared cross-worker batcher: ALL decode workers stage
        // into one keyed pool, each compute worker takes a coherent
        // single-qvec batch — same-table requests coalesce process-wide
        let (dec_tx, dec_rx) = shared_batcher::<BatchKey, DecodedJob>(
            cfg.decoded_capacity.max(1),
            staged_gauge,
            batch_hist,
        );

        let in_channels = engine.cfg.in_channels;
        let decode_handles: Vec<JoinHandle<()>> = (0..cfg.decode_workers.max(1))
            .map(|_| {
                let rx = admit_rx.clone();
                let tx = dec_tx.clone();
                let m = metrics.clone();
                let tr = tracer.clone();
                std::thread::spawn(move || decode_worker(rx, tx, m, tr, in_channels))
            })
            .collect();
        // decode workers hold the only senders into stage 2: when they
        // exit (admission drained + disconnected), stage 2 disconnects
        // and the compute pool drains out behind them
        drop(dec_tx);

        let local_batches = Arc::new(Counter::new());
        let compute_handles: Vec<JoinHandle<()>> = (0..cfg.compute_workers.max(1))
            .map(|_| {
                let rx = dec_rx.clone();
                let e = engine.clone();
                let m = metrics.clone();
                let a = aggregate.clone();
                let tr = tracer.clone();
                let lb = local_batches.clone();
                let max_batch = cfg.max_batch.max(1);
                std::thread::spawn(move || compute_worker(rx, e, m, a, tr, lb, max_batch))
            })
            .collect();

        NativePipeline {
            admit: Some(admit_tx),
            decode_handles,
            compute_handles,
            metrics,
            aggregate,
            registry,
            tracer,
            seq: AtomicU64::new(1),
            engine,
            local_batches,
        }
    }

    /// The engine shared by the compute pool.
    pub fn engine(&self) -> &Arc<NativeEngine> {
        &self.engine
    }

    /// Coordinator-compatible aggregate metrics.
    pub fn aggregate(&self) -> &Arc<Metrics> {
        &self.aggregate
    }

    /// The registry holding every instrument of this pipeline (the
    /// scrape source for `Stats` frames and `--metrics-dump`).
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// The span tracer, when one is attached.
    pub fn tracer(&self) -> Option<&Arc<Tracer>> {
        self.tracer.as_ref()
    }

    /// Precompute exploded maps for an encoder quality before traffic.
    pub fn warm(&self, quality: u8) {
        self.engine.warm(quality);
    }

    /// Compute batches THIS pipeline has served (per-shard warmup
    /// state; equals the aggregate `batches` counter when unsharded).
    pub fn batches_served(&self) -> u64 {
        self.local_batches.get()
    }

    /// Admit one request, or reject immediately with a typed error when
    /// the admission queue is at capacity.
    pub fn try_submit(
        &self,
        bytes: Vec<u8>,
    ) -> Result<Receiver<anyhow::Result<InferResponse>>, ServeError> {
        self.try_submit_request(ServeRequest::new(bytes))
    }

    /// [`NativePipeline::try_submit`] with per-request options: an
    /// already-expired deadline is rejected here with
    /// [`ServeError::DeadlineExceeded`], before the request ever
    /// occupies queue space.
    pub fn try_submit_request(
        &self,
        req: ServeRequest,
    ) -> Result<Receiver<anyhow::Result<InferResponse>>, ServeError> {
        let (tx, rx) = channel();
        self.submit_reply(req, Reply::Channel(tx)).map(|()| rx)
    }

    /// Admit one request whose reply goes to a completion sink instead
    /// of a channel — the reply-pump path of the socket front end.  On
    /// rejection the sink is returned disarmed inside the `Err`: the
    /// caller still owns the reply.
    pub fn submit_with_sink(&self, req: ServeRequest, sink: ReplySink) -> Result<(), ServeError> {
        self.submit_reply(req, Reply::Sink(sink))
    }

    fn submit_reply(&self, req: ServeRequest, mut reply: Reply) -> Result<(), ServeError> {
        let entered = Instant::now();
        let Some(admit) = self.admit.as_ref() else {
            reply.defuse();
            return Err(ServeError::ShuttingDown);
        };
        if expired(req.deadline) {
            self.metrics.deadline_expired.inc();
            reply.defuse();
            return Err(ServeError::DeadlineExceeded);
        }
        // sampling decision happens here, at admission
        let traced = self.tracer.as_ref().map_or(false, |t| t.sample_next());
        let request_id = if req.request_id != 0 {
            req.request_id
        } else {
            // the high bit keeps internal ids distinct from typical
            // client-assigned wire ids; ids only label trace spans, so
            // a determined collision is harmless
            0x8000_0000_0000_0000 | self.seq.fetch_add(1, Ordering::Relaxed)
        };
        let job = Job {
            bytes: req.bytes,
            deadline: req.deadline,
            submitted: entered,
            request_id,
            traced,
            reply,
        };
        match admit.try_send(job) {
            Ok(()) => {
                self.metrics.admitted.inc();
                self.metrics.decode.note_depth(admit.depth());
                if traced {
                    if let Some(t) = &self.tracer {
                        t.span(request_id, "admission", entered, Instant::now());
                    }
                }
                Ok(())
            }
            Err(SendRejected::Full(mut job)) => {
                self.metrics.rejected.inc();
                job.reply.defuse();
                Err(ServeError::QueueFull { capacity: admit.capacity() })
            }
            Err(SendRejected::Disconnected(mut job)) => {
                job.reply.defuse();
                Err(ServeError::ShuttingDown)
            }
        }
    }

    /// Blocking convenience: submit and wait for the reply.
    pub fn infer(&self, bytes: Vec<u8>) -> anyhow::Result<InferResponse> {
        self.try_submit(bytes)?
            .recv()
            .map_err(|_| anyhow::Error::new(ServeError::WorkerLost))?
    }

    /// Graceful drain: stop admitting, let both pools finish every
    /// queued request, then join all workers.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        drop(self.admit.take());
        for h in self.decode_handles.drain(..) {
            let _ = h.join();
        }
        for h in self.compute_handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for NativePipeline {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Decode one request's bytes to a single-image sparse batch + qvec.
/// Decoder failures keep their stable `JpegError::kind` label in the
/// message (`kind=truncated: ...`) so operators can bucket wire-visible
/// `Decode` responses without parsing free-form text.
fn decode_one(bytes: &[u8], in_channels: usize) -> Result<(SparseBlocks, [f32; 64]), ServeError> {
    let ci = codec::decode_to_coefficients(bytes)
        .map_err(|e| ServeError::Decode(format!("kind={}: {e}", e.kind())))?;
    if ci.channels != in_channels {
        return Err(ServeError::Decode(format!(
            "kind=geometry: expected {in_channels} channels, got {}",
            ci.channels
        )));
    }
    // one quant table across components (the single-J formulation the
    // exploded maps bake in); reject mixed-table files up front
    if ci.qtables[1..].iter().any(|t| *t != ci.qtables[0]) {
        return Err(ServeError::Decode(
            "kind=mixed-tables: mixed quant tables across components \
             (encode with separate_chroma_table=false)"
                .into(),
        ));
    }
    let qvec = ci.qvec(0);
    Ok((SparseBlocks::from_coeff_images(std::slice::from_ref(&ci)), qvec))
}

fn decode_worker(
    rx: Arc<BoundedReceiver<Job>>,
    tx: BatchSender<BatchKey, DecodedJob>,
    metrics: Arc<PipelineMetrics>,
    tracer: Option<Arc<Tracer>>,
    in_channels: usize,
) {
    while let Some(job) = rx.recv() {
        let picked_up = Instant::now();
        metrics
            .decode
            .queue_wait
            .record(picked_up.saturating_duration_since(job.submitted));
        // shed expired work before paying the entropy decode
        if expired(job.deadline) {
            metrics.deadline_expired.inc();
            job.reply.deliver(Err(anyhow::Error::new(ServeError::DeadlineExceeded)));
            continue;
        }
        match decode_one(&job.bytes, in_channels) {
            Ok((f0, qvec)) => {
                let decoded_at = Instant::now();
                metrics.decode.service.record(decoded_at.saturating_duration_since(picked_up));
                metrics.decode.processed.inc();
                if job.traced {
                    if let Some(t) = &tracer {
                        t.span(job.request_id, "decode", picked_up, decoded_at);
                    }
                }
                let (request_id, traced) = (job.request_id, job.traced);
                let key = (qvec.map(f32::to_bits), f0.dims());
                let dj = DecodedJob {
                    f0,
                    qvec,
                    tag: QualityTag::from_qvec(&qvec),
                    deadline: job.deadline,
                    submitted: job.submitted,
                    decoded_at,
                    enqueued_at: Instant::now(),
                    request_id,
                    traced,
                    reply: job.reply,
                };
                match tx.push(key, dj) {
                    Ok(()) => {
                        metrics.compute.note_depth(tx.depth());
                        // the blocking push IS the handoff: when the
                        // staging pool is full this span shows the
                        // backpressure stall
                        if traced {
                            if let Some(t) = &tracer {
                                t.span(request_id, "handoff", decoded_at, Instant::now());
                            }
                        }
                    }
                    // compute pool is gone: fail the request, keep draining
                    Err(dj) => {
                        dj.reply.deliver(Err(anyhow::Error::new(ServeError::ShuttingDown)));
                    }
                }
            }
            Err(e) => {
                metrics.decode.errors.inc();
                job.reply.deliver(Err(anyhow::Error::new(e)));
            }
        }
    }
}

fn compute_worker(
    rx: Arc<BatchReceiver<BatchKey, DecodedJob>>,
    engine: Arc<NativeEngine>,
    metrics: Arc<PipelineMetrics>,
    aggregate: Arc<Metrics>,
    tracer: Option<Arc<Tracer>>,
    local_batches: Arc<Counter>,
    max_batch: usize,
) {
    // the staging pool already hands out coherent single-key batches
    // (same quant table + block grid), coalesced across ALL decode
    // workers — no per-worker regrouping left to do here
    while let Some((_key, jobs)) = rx.next_batch(max_batch) {
        // last deadline gate: expired jobs never join a batch, so no
        // kernel time is spent on them
        let mut live = Vec::with_capacity(jobs.len());
        for job in jobs {
            if expired(job.deadline) {
                metrics.deadline_expired.inc();
                job.reply.deliver(Err(anyhow::Error::new(ServeError::DeadlineExceeded)));
            } else {
                live.push(job);
            }
        }
        if !live.is_empty() {
            serve_group(&engine, &metrics, &aggregate, &tracer, &local_batches, live);
        }
    }
}

fn serve_group(
    engine: &NativeEngine,
    metrics: &PipelineMetrics,
    aggregate: &Metrics,
    tracer: &Option<Arc<Tracer>>,
    local_batches: &Counter,
    group: Vec<DecodedJob>,
) {
    let t0 = Instant::now();
    for job in &group {
        metrics
            .compute
            .queue_wait
            .record(t0.saturating_duration_since(job.decoded_at));
        // batch-assembly: from the handoff enqueue to the batch
        // actually forming (queue residence + micro-batch coalescing)
        if job.traced {
            if let Some(t) = tracer {
                t.span(job.request_id, "batch-assembly", job.enqueued_at, t0);
            }
        }
    }
    let qvec = group[0].qvec;
    let batch = SparseBlocks::concat(group.iter().map(|j| &j.f0));
    // every forward feeds the per-op histograms; the resident executor
    // additionally reports per-layer nonzero fractions through a Tee
    // (the op recorder declines activations, so non-resident runs pay
    // no occupancy scans).  The concatenated batch MOVES into the
    // forward — no per-batch copy
    let resident = engine.mode == crate::serving::engine::NativeMode::SparseResident;
    let mut rec = OpRecorder::new(&metrics.plan_ops);
    let mut trace = crate::jpeg_domain::network::ResidencyTrace::new();
    let input = crate::jpeg_domain::plan::Act::Sparse(batch);
    let logits = if resident {
        let mut tee = Tee(&mut trace, &mut rec);
        engine.forward_with_observer(input, &qvec, Some(&mut tee))
    } else {
        engine.forward_with_observer(input, &qvec, Some(&mut rec))
    };
    if resident {
        metrics.sparsity.record(&trace);
    }
    let done = Instant::now();
    metrics.compute.service.record(done.saturating_duration_since(t0));
    metrics.compute.processed.add(group.len() as u64);
    aggregate.record_batch(group.len());
    local_batches.inc();

    let classes = logits.shape()[1];
    let preds = logits.argmax_last();
    for (i, job) in group.into_iter().enumerate() {
        let traced = job.traced;
        if traced {
            if let Some(t) = tracer {
                t.span(job.request_id, "compute", t0, done);
            }
        }
        let latency = job.submitted.elapsed();
        metrics.record_done(job.tag, latency);
        aggregate.request_latency.record(latency);
        let row = logits.data()[i * classes..(i + 1) * classes].to_vec();
        job.reply.deliver(Ok(InferResponse {
            logits: row,
            predicted: preds[i],
            latency,
            traced,
        }));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Dataset, Split, SynthKind};
    use crate::jpeg_domain::relu::Method;
    use crate::params::{ModelConfig, ParamSet};
    use crate::serving::engine::NativeMode;

    fn tiny_engine(mode: NativeMode) -> NativeEngine {
        let cfg = ModelConfig {
            name: "tiny".into(),
            in_channels: 1,
            num_classes: 4,
            widths: [2, 2, 2],
            image_size: 32,
        };
        let params = ParamSet::init(&cfg, 3);
        NativeEngine::new(cfg, params, 15, Method::Asm, 1, mode)
    }

    fn files(n: usize, quality: u8) -> Vec<(Vec<u8>, u32)> {
        Dataset::synthetic(SynthKind::Mnist, 2, n, 11).jpeg_bytes(Split::Test, quality)
    }

    #[test]
    fn roundtrip_and_tags() {
        let p = NativePipeline::start(tiny_engine(NativeMode::Sparse), PipelineConfig::default());
        p.warm(75);
        for (bytes, _) in files(3, 75) {
            let resp = p.infer(bytes).unwrap();
            assert_eq!(resp.logits.len(), 4);
            assert!(resp.predicted < 4);
        }
        let s = p.metrics.snapshot();
        assert_eq!(s.admitted, 3);
        assert_eq!(s.decode.processed, 3);
        assert_eq!(s.compute.processed, 3);
        // q75 traffic lands under the q75 tag
        assert_eq!(s.per_tag[1].1, 3, "{s}");
        p.shutdown();
    }

    #[test]
    fn resident_mode_serves_and_reports_sparsity() {
        let p = NativePipeline::start(
            tiny_engine(NativeMode::SparseResident),
            PipelineConfig::default(),
        );
        p.warm(75);
        for (bytes, _) in files(4, 75) {
            let resp = p.infer(bytes).unwrap();
            assert_eq!(resp.logits.len(), 4);
        }
        let s = p.metrics.snapshot();
        assert_eq!(s.compute.processed, 4);
        assert!(!s.layer_nonzero.is_empty(), "resident mode must report sparsity");
        assert!(s.layer_nonzero[0].1 > 0.0, "input density must be positive");
        for (label, d) in &s.layer_nonzero {
            assert!((0.0..=1.0).contains(d), "{label}: {d}");
        }
        p.shutdown();
    }

    #[test]
    fn bad_bytes_get_typed_decode_error() {
        let p = NativePipeline::start(tiny_engine(NativeMode::Sparse), PipelineConfig::default());
        let err = p.infer(vec![9, 9, 9]).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<ServeError>(),
            Some(ServeError::Decode(_))
        ));
        assert_eq!(p.metrics.snapshot().decode.errors, 1);
        p.shutdown();
    }

    #[test]
    fn submit_after_shutdown_not_possible_via_infer_path() {
        let p = NativePipeline::start(tiny_engine(NativeMode::Sparse), PipelineConfig::default());
        // shutdown consumes the pipeline; this test just verifies a
        // clean second shutdown path doesn't hang via Drop
        drop(p);
    }

    #[test]
    fn registry_scrape_covers_pipeline_queue_and_op_families() {
        let p = NativePipeline::start(tiny_engine(NativeMode::Sparse), PipelineConfig::default());
        p.warm(75);
        for (bytes, _) in files(2, 75) {
            p.infer(bytes).unwrap();
        }
        let text = p.registry().render();
        for needle in [
            "jd_pipeline_admitted_total 2",
            "jd_queue_depth{queue=\"admission\"} 0",
            "jd_queue_depth{queue=\"decoded\"} 0",
            "jd_stage_processed_total{stage=\"decode\"} 2",
            "jd_plan_op_us_count{op=\"fc\"} 2",
            "jd_requests_by_quality_total{quality=\"q75\"} 2",
            // the coordinator-compatible aggregate registers here too
            "jd_batches_total 2",
            "jd_server_requests_total 2",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        p.shutdown();
    }

    #[test]
    fn sampled_requests_emit_stage_spans() {
        let (tracer, buf) = Tracer::to_buffer(1);
        let p = NativePipeline::start_traced(
            tiny_engine(NativeMode::SparseResident),
            PipelineConfig::default(),
            Some(Arc::new(tracer)),
        );
        p.warm(75);
        for (bytes, _) in files(2, 75) {
            let resp = p.infer(bytes).unwrap();
            assert!(resp.traced, "sample 1 traces every request");
        }
        p.shutdown();
        let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
        for stage in ["admission", "decode", "handoff", "batch-assembly", "compute"] {
            assert!(
                text.contains(&format!("\"stage\":\"{stage}\"")),
                "missing {stage} span in:\n{text}"
            );
        }
        assert!(
            !text.contains("socket-write"),
            "in-process requests never reach the socket stage"
        );
        // every line is parseable JSONL with an internal (high-bit) id
        for line in text.lines() {
            let v = crate::json::parse(line).expect("span lines are JSON");
            assert!(v.get("request_id").as_f64().unwrap() >= 0x8000_0000_0000_0000u64 as f64);
        }
    }

    #[test]
    fn sink_submit_delivers_from_the_worker() {
        let p = NativePipeline::start(
            tiny_engine(NativeMode::SparseResident),
            PipelineConfig::default(),
        );
        p.warm(75);
        let (bytes, _) = files(1, 75).remove(0);
        let (tx, rx) = channel();
        let sink = ReplySink::new(move |r| drop(tx.send(r)));
        p.submit_with_sink(ServeRequest::new(bytes), sink).unwrap();
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.logits.len(), 4);
        // bad bytes reach the sink as the typed decode error
        let (tx, rx) = channel();
        p.submit_with_sink(ServeRequest::new(vec![1, 2, 3]), ReplySink::new(move |r| drop(tx.send(r))))
            .unwrap();
        let err = rx.recv().unwrap().unwrap_err();
        assert!(matches!(err.downcast_ref::<ServeError>(), Some(ServeError::Decode(_))));
        p.shutdown();
    }

    #[test]
    fn rejected_sink_is_defused_not_fired() {
        let p = NativePipeline::start(tiny_engine(NativeMode::Sparse), PipelineConfig::default());
        let (bytes, _) = files(1, 75).remove(0);
        let (tx, rx) = channel::<anyhow::Result<InferResponse>>();
        let sink = ReplySink::new(move |r| drop(tx.send(r)));
        // a deadline of "now" is already expired by the time the
        // admission check runs
        let req = ServeRequest::new(bytes).with_deadline(Instant::now());
        let err = p.submit_with_sink(req, sink).unwrap_err();
        assert_eq!(err, ServeError::DeadlineExceeded);
        // the sink must NOT fire (no WorkerLost ghost reply): the
        // caller owns the rejection reply
        assert!(rx.try_recv().is_err(), "defused sink must stay silent");
        p.shutdown();
    }

    #[test]
    fn dropped_sink_reports_worker_lost() {
        // a sink dropped unconsumed fires WorkerLost — the guarantee
        // that a dead worker can never strand a frontend completion
        let (tx, rx) = channel::<anyhow::Result<InferResponse>>();
        drop(ReplySink::new(move |r| drop(tx.send(r))));
        let err = rx.recv().unwrap().unwrap_err();
        assert!(matches!(err.downcast_ref::<ServeError>(), Some(ServeError::WorkerLost)));
    }

    #[test]
    fn disabled_tracer_marks_nothing_traced() {
        let p = NativePipeline::start(tiny_engine(NativeMode::Sparse), PipelineConfig::default());
        p.warm(75);
        let (bytes, _) = files(1, 75).remove(0);
        let resp = p.infer(bytes).unwrap();
        assert!(!resp.traced);
        p.shutdown();
    }
}
