//! Full baseline JPEG codec, built from scratch (paper §3.1 substrate).
//!
//! This is the system the paper's pipeline sits on: the coordinator's
//! "spatial" route decodes files all the way to pixels, while the "jpeg"
//! route stops after entropy decoding — the paper's JPEG transform domain
//! (output of encoder step 4) — and feeds coefficients to the network.
//!
//! Components:
//! * [`dct`] — forward/inverse 8x8 DCT (naive matrix form + separable
//!   fast path, cross-checked against each other)
//! * [`zigzag`] — the zigzag permutation and spatial-frequency bands
//! * [`quant`] — Annex-K tables + libjpeg quality scaling
//! * [`bits`] — MSB-first bit reader/writer with 0xFF byte stuffing
//! * [`huffman`] — baseline Huffman coding (Annex-K tables, canonical
//!   code construction, fast lookup decode)
//! * [`entropy`] — DC DPCM + AC run-length (ZRL/EOB) coefficient coding
//! * [`color`] — RGB <-> YCbCr (BT.601 full range, JFIF convention)
//! * [`jfif`] — the JFIF container: SOI/APP0/DQT/SOF0/DHT/SOS/EOI
//! * [`codec`] — top-level encode/decode plus `decode_to_coefficients`

pub mod bits;
pub mod codec;
pub mod color;
pub mod dct;
pub mod entropy;
pub mod huffman;
pub mod jfif;
pub mod quant;
pub mod zigzag;

pub use codec::{
    decode, decode_to_coefficients, encode, CoeffImage, Component, DecodedImage,
    EncodeOptions, PixelImage,
};
pub use quant::QuantTable;

/// JPEG block edge (8) and block size (64).
pub const BLK: usize = 8;
pub const NCOEF: usize = 64;
/// Number of spatial-frequency bands of an 8x8 DCT (paper: 15).
pub const NUM_BANDS: usize = 15;

/// Errors across the codec.
#[derive(Debug, thiserror::Error)]
pub enum JpegError {
    #[error("invalid JPEG stream: {0}")]
    Invalid(String),
    #[error("unsupported JPEG feature: {0}")]
    Unsupported(String),
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

pub type Result<T> = std::result::Result<T, JpegError>;
