"""L2 model: the paper's Figure-3 residual classifier, in both domains.

Architecture (paper §5.1): stem conv + three residual blocks, the final two
downsampling by 2, so a 32x32 input ends as a single 8x8 JPEG block; global
average pooling then a fully-connected classifier.

Both `spatial_forward` and `jpeg_forward` consume the SAME flat parameter
dict — model conversion (paper §4.6) is the identity on parameters, exactly
as in the paper: the convolution explosion consumes spatial filters
directly and BN parameters carry over unchanged.

Parameters are a flat {name: array} dict; `param_specs` fixes the order
(sorted names) and init metadata that the rust runtime uses.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from . import layers as L


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    in_channels: int
    num_classes: int
    widths: tuple[int, int, int] = (8, 16, 32)
    image_size: int = 32


CONFIGS = {
    "mnist": ModelConfig("mnist", 1, 10),
    "cifar10": ModelConfig("cifar10", 3, 10),
    "cifar100": ModelConfig("cifar100", 3, 100),
}


# ---------------------------------------------------------------------------
# Parameter specification
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: tuple[int, ...]
    init: str          # "he_normal" | "zeros" | "ones"
    fan_in: int
    trainable: bool


def _conv_spec(name, cout, cin, k):
    return ParamSpec(name, (cout, cin, k, k), "he_normal", cin * k * k, True)


def _bn_specs(prefix, c):
    return [
        ParamSpec(f"{prefix}.gamma", (c,), "ones", c, True),
        ParamSpec(f"{prefix}.beta", (c,), "zeros", c, True),
        ParamSpec(f"{prefix}.rmean", (c,), "zeros", c, False),
        ParamSpec(f"{prefix}.rvar", (c,), "ones", c, False),
    ]


def param_specs(cfg: ModelConfig) -> list[ParamSpec]:
    w1, w2, w3 = cfg.widths
    specs: list[ParamSpec] = []
    specs.append(_conv_spec("stem.conv.w", w1, cfg.in_channels, 3))
    specs += _bn_specs("stem.bn", w1)
    # block1: w1 -> w1 stride 1, identity shortcut
    specs.append(_conv_spec("block1.conv1.w", w1, w1, 3))
    specs += _bn_specs("block1.bn1", w1)
    specs.append(_conv_spec("block1.conv2.w", w1, w1, 3))
    specs += _bn_specs("block1.bn2", w1)
    # block2: w1 -> w2 stride 2, projection shortcut
    specs.append(_conv_spec("block2.conv1.w", w2, w1, 3))
    specs += _bn_specs("block2.bn1", w2)
    specs.append(_conv_spec("block2.conv2.w", w2, w2, 3))
    specs += _bn_specs("block2.bn2", w2)
    specs.append(_conv_spec("block2.proj.w", w2, w1, 1))
    specs += _bn_specs("block2.projbn", w2)
    # block3: w2 -> w3 stride 2, projection shortcut
    specs.append(_conv_spec("block3.conv1.w", w3, w2, 3))
    specs += _bn_specs("block3.bn1", w3)
    specs.append(_conv_spec("block3.conv2.w", w3, w3, 3))
    specs += _bn_specs("block3.bn2", w3)
    specs.append(_conv_spec("block3.proj.w", w3, w2, 1))
    specs += _bn_specs("block3.projbn", w3)
    # classifier
    specs.append(ParamSpec("fc.w", (w3, cfg.num_classes), "he_normal", w3, True))
    specs.append(ParamSpec("fc.b", (cfg.num_classes,), "zeros", w3, True))
    return sorted(specs, key=lambda s: s.name)


def init_params(cfg: ModelConfig, seed: int) -> dict[str, jnp.ndarray]:
    rng = np.random.default_rng(seed)
    params = {}
    for s in param_specs(cfg):
        if s.init == "he_normal":
            std = np.sqrt(2.0 / s.fan_in)
            params[s.name] = jnp.asarray(
                rng.normal(0.0, std, s.shape).astype(np.float32))
        elif s.init == "zeros":
            params[s.name] = jnp.zeros(s.shape, jnp.float32)
        elif s.init == "ones":
            params[s.name] = jnp.ones(s.shape, jnp.float32)
        else:
            raise ValueError(s.init)
    return params


def flatten_params(cfg, params):
    return [params[s.name] for s in param_specs(cfg)]


def unflatten_params(cfg, leaves):
    specs = param_specs(cfg)
    assert len(specs) == len(leaves)
    return {s.name: leaf for s, leaf in zip(specs, leaves)}


# ---------------------------------------------------------------------------
# Spatial network
# ---------------------------------------------------------------------------
def _sp_bn(p, new, prefix, x, training):
    y, rm, rv = L.batch_norm(
        x, p[f"{prefix}.gamma"], p[f"{prefix}.beta"],
        p[f"{prefix}.rmean"], p[f"{prefix}.rvar"], training=training)
    new[f"{prefix}.rmean"], new[f"{prefix}.rvar"] = rm, rv
    return y


def _sp_block(p, new, prefix, x, stride, training):
    y = L.conv2d(x, p[f"{prefix}.conv1.w"], stride=stride)
    y = _sp_bn(p, new, f"{prefix}.bn1", y, training)
    y = L.relu(y)
    y = L.conv2d(y, p[f"{prefix}.conv2.w"], stride=1)
    y = _sp_bn(p, new, f"{prefix}.bn2", y, training)
    if stride != 1:
        sc = L.conv2d(x, p[f"{prefix}.proj.w"], stride=stride)
        sc = _sp_bn(p, new, f"{prefix}.projbn", sc, training)
    else:
        sc = x
    return L.relu(y + sc)


def spatial_forward(cfg: ModelConfig, params, x, *, training: bool = False):
    """(N, C, 32, 32) pixels -> logits.  Returns (logits, updated_params)."""
    p = dict(params)
    new = dict(params)
    y = L.conv2d(x, p["stem.conv.w"], stride=1)
    y = _sp_bn(p, new, "stem.bn", y, training)
    y = L.relu(y)
    y = _sp_block(p, new, "block1", y, 1, training)
    y = _sp_block(p, new, "block2", y, 2, training)
    y = _sp_block(p, new, "block3", y, 2, training)
    g = L.global_avg_pool(y)
    logits = L.linear(g, p["fc.w"], p["fc.b"])
    return logits, new


# ---------------------------------------------------------------------------
# JPEG-domain network (paper §4) — same parameters, coefficient activations
# ---------------------------------------------------------------------------
def _jp_bn(p, new, prefix, f, qvec, training):
    y, rm, rv = L.jpeg_batch_norm(
        f, qvec, p[f"{prefix}.gamma"], p[f"{prefix}.beta"],
        p[f"{prefix}.rmean"], p[f"{prefix}.rvar"], training=training)
    new[f"{prefix}.rmean"], new[f"{prefix}.rvar"] = rm, rv
    return y


def _jp_block(p, new, prefix, f, qvec, freq_mask, stride, training, method):
    y = L.jpeg_conv_dcc(f, p[f"{prefix}.conv1.w"], qvec, stride=stride)
    y = _jp_bn(p, new, f"{prefix}.bn1", y, qvec, training)
    y = L.jpeg_relu(y, qvec, freq_mask, method=method)
    y = L.jpeg_conv_dcc(y, p[f"{prefix}.conv2.w"], qvec, stride=1)
    y = _jp_bn(p, new, f"{prefix}.bn2", y, qvec, training)
    if stride != 1:
        sc = L.jpeg_conv_dcc(f, p[f"{prefix}.proj.w"], qvec, stride=stride)
        sc = _jp_bn(p, new, f"{prefix}.projbn", sc, qvec, training)
    else:
        sc = f
    return L.jpeg_relu(L.jpeg_add(y, sc), qvec, freq_mask, method=method)


def jpeg_forward(cfg: ModelConfig, params, coeffs, qvec, freq_mask, *,
                 training: bool = False, method: str = "asm"):
    """(N, C, 4, 4, 64) JPEG-domain coefficients -> logits.

    `qvec` is the (64,) quantization vector the coefficients were divided
    by; `freq_mask` the (64,) ASM band mask; `method` "asm" or "apx".
    Returns (logits, updated_params).
    """
    p = dict(params)
    new = dict(params)
    f = L.jpeg_conv_dcc(coeffs, p["stem.conv.w"], qvec, stride=1)
    f = _jp_bn(p, new, "stem.bn", f, qvec, training)
    f = L.jpeg_relu(f, qvec, freq_mask, method=method)
    f = _jp_block(p, new, "block1", f, qvec, freq_mask, 1, training, method)
    f = _jp_block(p, new, "block2", f, qvec, freq_mask, 2, training, method)
    f = _jp_block(p, new, "block3", f, qvec, freq_mask, 2, training, method)
    g = L.jpeg_global_avg_pool(f, qvec)
    logits = L.linear(g, p["fc.w"], p["fc.b"])
    return logits, new


# ---------------------------------------------------------------------------
# Exploded-map inference path (precomputed Xi per conv layer, paper §4.1:
# "the map can be precomputed to speed up inference")
# ---------------------------------------------------------------------------
def jpeg_forward_fused(cfg: ModelConfig, params, coeffs, qvec):
    """Optimized JPEG-route inference (paper §4.1 "the map can be
    precomputed to speed up inference", taken to its fixed point).

    For eval the whole JPEG-domain network is the spatial network
    conjugated by the (exact, linear) JPEG transform; composing the
    per-layer decode/encode pairs cancels them everywhere except the
    input, leaving one Pallas block-transform decode fused into the stem.
    Mathematically identical to `jpeg_forward` at phi = 15; this is the
    graph the serving fast path uses (DESIGN.md §8 / EXPERIMENTS.md §Perf).
    The decode here is the plain-XLA GEMM (not the interpret-mode Pallas
    kernel): interpret lowering wraps the matmul in a while loop that the
    CPU backend cannot fuse or parallelize — measured 2-3x slower than the
    bare dot (EXPERIMENTS.md §Perf iteration 2).
    """
    from . import jpeg_ops as jo
    x = jo.decode(coeffs, qvec)
    logits, _ = spatial_forward(cfg, params, x, training=False)
    return logits


#: (param name, stride) for every convolution in the network
CONV_LAYOUT = [
    ("stem.conv.w", 1),
    ("block1.conv1.w", 1), ("block1.conv2.w", 1),
    ("block2.conv1.w", 2), ("block2.conv2.w", 1), ("block2.proj.w", 2),
    ("block3.conv1.w", 2), ("block3.conv2.w", 1), ("block3.proj.w", 2),
]


def explode_all(cfg: ModelConfig, params, qvec):
    """Materialize every conv's exploded map (the paper's precompute)."""
    return {name: L.explode_conv(params[name], qvec, stride=s)
            for name, s in CONV_LAYOUT}


def jpeg_forward_exploded(cfg: ModelConfig, params, xis, coeffs, qvec,
                          freq_mask, *, method: str = "asm"):
    """Inference with precomputed exploded maps (eval mode only)."""
    p = dict(params)
    new = dict(params)

    def conv(f, name, stride):
        # cout from the map itself so exploded graphs need no conv leaves
        cout = xis[name].shape[1] // 64
        return L.jpeg_conv_exploded(f, xis[name], qvec, cout=cout, stride=stride)

    def block(prefix, f, stride):
        y = conv(f, f"{prefix}.conv1.w", stride)
        y = _jp_bn(p, new, f"{prefix}.bn1", y, qvec, False)
        y = L.jpeg_relu(y, qvec, freq_mask, method=method)
        y = conv(y, f"{prefix}.conv2.w", 1)
        y = _jp_bn(p, new, f"{prefix}.bn2", y, qvec, False)
        if stride != 1:
            sc = conv(f, f"{prefix}.proj.w", stride)
            sc = _jp_bn(p, new, f"{prefix}.projbn", sc, qvec, False)
        else:
            sc = f
        return L.jpeg_relu(y + sc, qvec, freq_mask, method=method)

    f = conv(coeffs, "stem.conv.w", 1)
    f = _jp_bn(p, new, "stem.bn", f, qvec, False)
    f = L.jpeg_relu(f, qvec, freq_mask, method=method)
    f = block("block1", f, 1)
    f = block("block2", f, 2)
    f = block("block3", f, 2)
    g = L.jpeg_global_avg_pool(f, qvec)
    return L.linear(g, p["fc.w"], p["fc.b"])
