"""Multilinear JPEG machinery (paper §3).

Implements the linear maps that make up the JPEG transform J = S∘Z∘D∘B
(block split, orthonormal 8x8 DCT, zigzag, quantization divide) and their
inverses, as plain numpy constants + jnp ops.  These constants are folded
into the Pallas kernels and the lowered HLO artifacts.

Conventions (DESIGN.md §6):
  * orthonormal 2-D DCT:  Y = A @ x_flat  with A @ A.T = I; Y[(0,0)] = 8*mean
  * the "JPEG transform domain" value is  y_k = (Z A x)_k / q_k  (after
    step 4 of the encoder, BEFORE rounding)
  * coefficient layout: (..., Bh, Bw, 64)  with the 64-axis in zigzag order
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

BLK = 8
NCOEF = BLK * BLK  # 64
NUM_BANDS = 2 * BLK - 1  # 15 spatial-frequency bands of an 8x8 DCT

# ---------------------------------------------------------------------------
# Zigzag (paper eq. 6): ZIGZAG[k] = raster index (8*alpha+beta) of the k-th
# zigzag-ordered coefficient.
# ---------------------------------------------------------------------------
ZIGZAG = np.array([
    0, 1, 8, 16, 9, 2, 3, 10,
    17, 24, 32, 25, 18, 11, 4, 5,
    12, 19, 26, 33, 40, 48, 41, 34,
    27, 20, 13, 6, 7, 14, 21, 28,
    35, 42, 49, 56, 57, 50, 43, 36,
    29, 22, 15, 23, 30, 37, 44, 51,
    58, 59, 52, 45, 38, 31, 39, 46,
    53, 60, 61, 54, 47, 55, 62, 63], dtype=np.int64)

#: inverse permutation: UNZIGZAG[raster] = zigzag position
UNZIGZAG = np.argsort(ZIGZAG)

#: spatial-frequency band (alpha+beta) of each zigzag-ordered coefficient
BAND = np.array([(z // BLK) + (z % BLK) for z in ZIGZAG], dtype=np.int64)


def dct_matrix_1d(n: int = BLK) -> np.ndarray:
    """Orthonormal 1-D DCT-II matrix D with Y = D @ x,  D @ D.T = I."""
    k = np.arange(n)[:, None].astype(np.float64)
    t = np.arange(n)[None, :].astype(np.float64)
    d = np.cos((2 * t + 1) * k * np.pi / (2 * n))
    d *= np.sqrt(2.0 / n)
    d[0, :] = np.sqrt(1.0 / n)
    return d


def dct_matrix_2d() -> np.ndarray:
    """(64, 64) orthonormal 2-D DCT on flattened 8x8 blocks (paper eq. 5).

    A[(8a+b), (8m+n)] = D[a,m] * D[b,n];  Y_flat = A @ x_flat.
    """
    d = dct_matrix_1d()
    return np.kron(d, d)


#: (64,64) zigzag-ordered forward DCT:  y_zz = ZA @ x_flat (paper's Z∘D)
ZA = dct_matrix_2d()[ZIGZAG, :]


def band_mask(num_freqs: int) -> np.ndarray:
    """0/1 vector over zigzag coefficients keeping the lowest `num_freqs`
    spatial-frequency bands (paper §4.2: all phi with band(phi) < k).

    num_freqs ranges 1..15; 15 keeps all 64 coefficients (exact ReLU).
    """
    if not 1 <= num_freqs <= NUM_BANDS:
        raise ValueError(f"num_freqs must be in 1..{NUM_BANDS}")
    return (BAND < num_freqs).astype(np.float32)


def dec_matrix(qvec: np.ndarray) -> np.ndarray:
    """(64,64) row-vector decode map: x_flat = f_zz @ dec  (dequant+unzigzag
    +IDCT).  dec[k, p] = ZA[k, p] * q_k."""
    return (ZA * np.asarray(qvec, dtype=np.float64)[:, None]).astype(np.float32)


def enc_matrix(qvec: np.ndarray) -> np.ndarray:
    """(64,64) row-vector encode map: f_zz = x_flat @ enc (DCT+zigzag+quant).
    enc[p, k] = ZA[k, p] / q_k;  dec @ enc = I."""
    return (ZA / np.asarray(qvec, dtype=np.float64)[:, None]).T.astype(np.float32)


# ---------------------------------------------------------------------------
# Quantization tables (paper eq. 7 / 9)
# ---------------------------------------------------------------------------
#: flat table — the paper's "losslessly JPEG compressed" setting
QTABLE_FLAT = np.ones(NCOEF, dtype=np.float32)

#: Annex K.1 luminance table (raster order)
ANNEX_K_LUMA = np.array([
    16, 11, 10, 16, 24, 40, 51, 61,
    12, 12, 14, 19, 26, 58, 60, 55,
    14, 13, 16, 24, 40, 57, 69, 56,
    14, 17, 22, 29, 51, 87, 80, 62,
    18, 22, 37, 56, 68, 109, 103, 77,
    24, 35, 55, 64, 81, 104, 113, 92,
    49, 64, 78, 87, 103, 121, 120, 101,
    72, 92, 95, 98, 112, 100, 103, 99], dtype=np.float64)

#: Annex K.2 chrominance table (raster order)
ANNEX_K_CHROMA = np.array([
    17, 18, 24, 47, 99, 99, 99, 99,
    18, 21, 26, 66, 99, 99, 99, 99,
    24, 26, 56, 99, 99, 99, 99, 99,
    47, 66, 99, 99, 99, 99, 99, 99,
    99, 99, 99, 99, 99, 99, 99, 99,
    99, 99, 99, 99, 99, 99, 99, 99,
    99, 99, 99, 99, 99, 99, 99, 99,
    99, 99, 99, 99, 99, 99, 99, 99], dtype=np.float64)


def quality_scale(base_raster: np.ndarray, quality: int) -> np.ndarray:
    """libjpeg-style quality scaling; returns a zigzag-ordered f32 table."""
    if not 1 <= quality <= 100:
        raise ValueError("quality in 1..100")
    scale = 5000.0 / quality if quality < 50 else 200.0 - 2.0 * quality
    q = np.floor((base_raster * scale + 50.0) / 100.0)
    q = np.clip(q, 1.0, 255.0)
    return q[ZIGZAG].astype(np.float32)


# ---------------------------------------------------------------------------
# Block split / merge (paper's B tensor, eq. 4) and encode/decode
# ---------------------------------------------------------------------------
def blockify(x: jnp.ndarray) -> jnp.ndarray:
    """(N, C, H, W) -> (N, C, H/8, W/8, 64) flattened raster blocks."""
    n, c, h, w = x.shape
    assert h % BLK == 0 and w % BLK == 0, (h, w)
    x = x.reshape(n, c, h // BLK, BLK, w // BLK, BLK)
    x = x.transpose(0, 1, 2, 4, 3, 5)
    return x.reshape(n, c, h // BLK, w // BLK, NCOEF)


def unblockify(b: jnp.ndarray) -> jnp.ndarray:
    """(N, C, Bh, Bw, 64) -> (N, C, 8*Bh, 8*Bw)."""
    n, c, bh, bw, _ = b.shape
    x = b.reshape(n, c, bh, bw, BLK, BLK)
    x = x.transpose(0, 1, 2, 4, 3, 5)
    return x.reshape(n, c, bh * BLK, bw * BLK)


def encode(x: jnp.ndarray, qvec: jnp.ndarray) -> jnp.ndarray:
    """Image (N,C,H,W) -> JPEG-domain coefficients (N,C,Bh,Bw,64).

    y = (Z A x) / q per block; no rounding (paper's transform domain).
    """
    blocks = blockify(x)
    za = jnp.asarray(ZA, dtype=x.dtype)
    return (blocks @ za.T) / qvec


def decode(coeffs: jnp.ndarray, qvec: jnp.ndarray) -> jnp.ndarray:
    """JPEG-domain coefficients -> image (exact inverse of `encode`)."""
    za = jnp.asarray(ZA, dtype=coeffs.dtype)
    blocks = (coeffs * qvec) @ za
    return unblockify(blocks)


# ---------------------------------------------------------------------------
# Harmonic mixing tensor (paper eq. 17 / 20), materialized form.
#
# H[k', k, p] with p the flat spatial pixel: applying a spatial mask G to a
# zigzag DCT block F is  F'_{k'} = sum_{k,p} H[k',k,p] F_k G_p.
# The kernels use the factored (3-matmul) form; this materialization exists
# for tests and for the paper-faithful einsum ablation.
# ---------------------------------------------------------------------------
def harmonic_mixing_tensor(qvec: np.ndarray) -> np.ndarray:
    """(64, 64, 64) tensor: out_zz[k'] = sum_{k,p} H[k',k,p] f_zz[k] mask[p].

    Includes (de)quantization, i.e. the paper's eq. 20 form.
    """
    dec = ZA.T * qvec[None, :]            # x_p = sum_k dec[p,k] f_k
    enc = ZA / qvec[:, None]              # f'_{k'} = sum_p enc[k',p] x'_p
    # out[k'] = sum_p enc[k',p] * (sum_k dec[p,k] f_k) * mask[p]
    return np.einsum("ap,pk->akp", enc, dec).astype(np.float32)
