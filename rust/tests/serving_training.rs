//! Integration: the full train -> checkpoint -> serve lifecycle, and
//! failure injection on the serving path.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use jpegdomain::coordinator::router::Route;
use jpegdomain::coordinator::server::{Server, ServerConfig};
use jpegdomain::coordinator::training::{TrainConfig, TrainDomain, Trainer};
use jpegdomain::coordinator::BatcherConfig;
use jpegdomain::data::{Dataset, Split, SynthKind};
use jpegdomain::runtime::{Engine, Session};

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(dir)
}

#[test]
fn train_checkpoint_serve_lifecycle() {
    let Some(dir) = artifacts() else { return };
    let ckpt = std::env::temp_dir().join("lifecycle.ckpt");

    // 1. train a model to better-than-chance accuracy
    let engine = Arc::new(Engine::new(&dir).unwrap());
    let session = Session::new(engine, "mnist").unwrap();
    let data = Dataset::synthetic(SynthKind::Mnist, 600, 200, 21);
    let cfg = TrainConfig {
        domain: TrainDomain::Spatial,
        steps: 80,
        eval_batches: 4,
        checkpoint: Some(ckpt.clone()),
        ..Default::default()
    };
    let (_, report) = Trainer::new(&session, &data, cfg).run().unwrap();
    assert!(report.test_accuracy > 0.3, "{}", report.test_accuracy);
    drop(session);

    // 2. serve from the checkpoint over the JPEG pipeline; accuracy must
    //    transfer (model conversion at system level)
    let server = Server::start_default(
        dir,
        "mnist".into(),
        Some(ckpt.clone()),
        0,
        ServerConfig { route: Route::Jpeg, ..Default::default() },
    );
    let files = data.jpeg_bytes(Split::Test, 95);
    let mut correct = 0usize;
    let n = 80;
    for (bytes, label) in files.iter().take(n) {
        let resp = server.infer(bytes.clone()).unwrap();
        if resp.predicted == *label as usize {
            correct += 1;
        }
    }
    let acc = correct as f32 / n as f32;
    // JPEG-side serving accuracy should be close to the spatial test
    // accuracy (identical math, different input representation/quality)
    assert!(
        acc > report.test_accuracy - 0.15,
        "served acc {acc} vs trained {}",
        report.test_accuracy
    );
    let snap = server.metrics.snapshot();
    assert_eq!(snap.requests as usize, n);
    server.shutdown();
    std::fs::remove_file(ckpt).unwrap();
}

#[test]
fn server_survives_poison_requests_interleaved() {
    let Some(dir) = artifacts() else { return };
    let server = Server::start_default(
        dir,
        "mnist".into(),
        None,
        0,
        ServerConfig {
            route: Route::Jpeg,
            batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(10) },
            ..Default::default()
        },
    );
    let data = Dataset::synthetic(SynthKind::Mnist, 2, 4, 5);
    let files = data.jpeg_bytes(Split::Test, 95);
    for i in 0..12 {
        if i % 3 == 0 {
            // poison: truncated JPEG
            let mut bad = files[0].0.clone();
            bad.truncate(bad.len() / 3);
            assert!(server.infer(bad).is_err(), "request {i}");
        } else {
            assert!(server.infer(files[i % files.len()].0.clone()).is_ok(), "request {i}");
        }
    }
    server.shutdown();
}

#[test]
fn jpeg_domain_training_transfers_to_spatial_pipeline() {
    // train IN the jpeg domain, serve over the SPATIAL pipeline: the
    // shared parameterization works in both directions (phi = 15)
    let Some(dir) = artifacts() else { return };
    let engine = Arc::new(Engine::new(&dir).unwrap());
    let session = Session::new(engine, "mnist").unwrap();
    let data = Dataset::synthetic(SynthKind::Mnist, 400, 160, 31);
    let cfg = TrainConfig {
        domain: TrainDomain::Jpeg {
            num_freqs: 15,
            method: jpegdomain::jpeg_domain::relu::Method::Asm,
        },
        steps: 60,
        eval_batches: 4,
        ..Default::default()
    };
    let (state, report) = Trainer::new(&session, &data, cfg).run().unwrap();
    assert!(report.test_accuracy > 0.25);

    // evaluate through the spatial pipeline
    let trainer_spatial = Trainer::new(
        &session,
        &data,
        TrainConfig {
            domain: TrainDomain::Spatial,
            eval_batches: 4,
            ..Default::default()
        },
    );
    let acc_spatial = trainer_spatial
        .evaluate(&state.params, Split::Test)
        .unwrap();
    assert!(
        (acc_spatial - report.test_accuracy).abs() < 1e-3,
        "spatial {acc_spatial} vs jpeg {}",
        report.test_accuracy
    );
}
