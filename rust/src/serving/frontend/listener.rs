//! The TCP acceptor + connection worker pool over the native pipeline.
//!
//! One thread accepts; each connection gets a worker thread that parses
//! request frames and feeds [`NativePipeline::try_submit_request`].
//! Replies are written by short-lived per-request waiter threads through
//! a mutex-serialized write half, so responses stream back **out of
//! order** — the request id in the frame header is the only correlation.
//! Everything is `std::net` + `std::thread`; no async runtime.
//!
//! Per-connection flow control: at most `max_inflight` submitted
//! requests may be awaiting replies; past that the reader stops pulling
//! frames off the socket, which backpressures the client through TCP —
//! on top of the pipeline's own bounded admission queue, whose overflow
//! surfaces as the typed [`WireCode::QueueFull`] response.
//!
//! ## Slow start
//!
//! A freshly started server has an empty per-qvec `ExplodedModel` cache;
//! the first batch of each quant table pays a seconds-long precompute.
//! Until the pipeline has served `warmup_batches` compute batches,
//! socket requests are rejected with the typed [`WireCode::WarmingUp`]
//! code instead of being queued behind that cliff.  In-process callers
//! (the warmup driver in `repro serve --listen`) bypass the gate, which
//! is what lets the cache warm in the first place.  The gate is sticky:
//! once open it never closes.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::serving::error::ServeError;
use crate::serving::metrics::FrontendMetrics;
use crate::serving::pipeline::{NativePipeline, ServeRequest};

use super::protocol::{
    encode_response, encode_stats_response, read_incoming, FrameError, IncomingFrame,
    ResponseBody, ResponseFrame, WireCode,
};

/// Socket front end settings (`[serve] listen_addr` / `warmup_batches`;
/// CLI flags override).
#[derive(Clone, Debug)]
pub struct FrontendConfig {
    /// Address to bind (`"127.0.0.1:0"` = loopback, ephemeral port).
    pub listen_addr: String,
    /// Compute batches the pipeline must have served before socket
    /// traffic is admitted; `0` disables the slow-start gate.
    pub warmup_batches: u64,
    /// Per-connection cap on submitted-but-unanswered requests; past it
    /// the reader stops pulling frames (TCP backpressure).
    pub max_inflight: usize,
}

impl Default for FrontendConfig {
    fn default() -> Self {
        FrontendConfig {
            listen_addr: "127.0.0.1:0".to_string(),
            warmup_batches: 0,
            max_inflight: 64,
        }
    }
}

/// Sticky slow-start gate over the pipeline's served-batch counter.
///
/// The counter is **global**, not per quant table: the gate shields
/// the startup cliff, while the per-qvec precompute for *declared*
/// tables is paid up front by `repro serve --listen`'s
/// `pipeline.warm(q)` calls.  A request arriving with a quant table
/// nobody warmed still pays its precompute in-request (admission
/// cannot know the table without decoding); per-qvec gating is a
/// ROADMAP follow-up.
struct WarmupGate {
    need: u64,
    warmed: AtomicBool,
}

impl WarmupGate {
    fn new(need: u64) -> WarmupGate {
        WarmupGate { need, warmed: AtomicBool::new(need == 0) }
    }

    fn is_warm(&self, pipeline: &NativePipeline) -> bool {
        if self.warmed.load(Ordering::Relaxed) {
            return true;
        }
        if pipeline.aggregate().batches.get() >= self.need {
            self.warmed.store(true, Ordering::Relaxed);
            return true;
        }
        false
    }
}

/// Counting gauge with waiters: bounds per-connection in-flight
/// requests and lets the connection worker drain before closing.
#[derive(Default)]
struct Inflight {
    count: Mutex<usize>,
    changed: Condvar,
}

impl Inflight {
    fn inc_below(&self, cap: usize) {
        let mut n = self.count.lock().unwrap();
        while *n >= cap.max(1) {
            n = self.changed.wait(n).unwrap();
        }
        *n += 1;
    }

    fn dec(&self) {
        let mut n = self.count.lock().unwrap();
        *n -= 1;
        self.changed.notify_all();
    }

    fn wait_zero(&self) {
        let mut n = self.count.lock().unwrap();
        while *n > 0 {
            n = self.changed.wait(n).unwrap();
        }
    }
}

/// A running socket front end.  Dropping (or [`SocketFrontend::shutdown`])
/// stops the acceptor, closes every connection, and joins all workers;
/// the pipeline itself is left running (shut it down after).
pub struct SocketFrontend {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<(TcpStream, JoinHandle<()>)>>>,
    /// Per-connection / per-wire-code counters.
    pub metrics: Arc<FrontendMetrics>,
}

impl SocketFrontend {
    /// Bind `cfg.listen_addr` and start accepting.  Fails fast when the
    /// address cannot be bound (taken port, bad syntax).
    pub fn start(
        pipeline: Arc<NativePipeline>,
        cfg: FrontendConfig,
    ) -> anyhow::Result<SocketFrontend> {
        let listener = TcpListener::bind(&cfg.listen_addr)
            .map_err(|e| anyhow::anyhow!("bind {}: {e}", cfg.listen_addr))?;
        let local_addr = listener.local_addr()?;
        // non-blocking accept so the stop flag is honored promptly
        listener.set_nonblocking(true)?;
        // frontend counters live in the pipeline's registry, so one
        // Stats scrape covers both layers
        let metrics = Arc::new(FrontendMetrics::register(pipeline.registry()));
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<(TcpStream, JoinHandle<()>)>>> =
            Arc::new(Mutex::new(Vec::new()));
        let gate = Arc::new(WarmupGate::new(cfg.warmup_batches));
        let max_inflight = cfg.max_inflight.max(1);

        let acceptor = {
            let stop = stop.clone();
            let conns = conns.clone();
            let metrics = metrics.clone();
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            let _ = stream.set_nodelay(true);
                            let _ = stream.set_nonblocking(false);
                            let Ok(track) = stream.try_clone() else { continue };
                            let pipeline = pipeline.clone();
                            let gate = gate.clone();
                            let metrics = metrics.clone();
                            let stop = stop.clone();
                            let handle = std::thread::spawn(move || {
                                handle_connection(
                                    stream,
                                    pipeline,
                                    gate,
                                    metrics,
                                    max_inflight,
                                    stop,
                                )
                            });
                            let mut guard = conns.lock().unwrap();
                            // reap finished workers so long-lived servers
                            // don't accumulate dead handles
                            let mut i = 0;
                            while i < guard.len() {
                                if guard[i].1.is_finished() {
                                    let (_, h) = guard.swap_remove(i);
                                    let _ = h.join();
                                } else {
                                    i += 1;
                                }
                            }
                            guard.push((track, handle));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                        // a bad accept must never wedge the acceptor
                        Err(_) => std::thread::sleep(Duration::from_millis(5)),
                    }
                }
            })
        };

        Ok(SocketFrontend {
            local_addr,
            stop,
            acceptor: Some(acceptor),
            conns,
            metrics,
        })
    }

    /// The bound address (resolves the port when `:0` was requested).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Stop accepting, close every connection, join all workers.
    pub fn shutdown(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        let conns = std::mem::take(&mut *self.conns.lock().unwrap());
        for (stream, handle) in conns {
            // unblock the reader but leave the write half open —
            // shutdown applies socket-wide across the dup'd fds, and
            // the worker still has in-flight replies to flush (the
            // pipeline is still up); the worker FINs the write side
            // itself once its waiters drain
            let _ = stream.shutdown(std::net::Shutdown::Read);
            let _ = handle.join();
        }
    }
}

impl Drop for SocketFrontend {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

/// How long a reply write may block before the connection is declared
/// dead.  A client that stops reading fills its TCP receive window and
/// would otherwise park a waiter thread in `write_all` forever —
/// pinning the inflight count, the connection worker's drain, and
/// ultimately [`SocketFrontend::shutdown`].
const WRITE_STALL_LIMIT: Duration = Duration::from_secs(30);

/// Serialize one response frame onto the shared write half.  A write
/// error (peer gone, or stalled past [`WRITE_STALL_LIMIT`]) kills the
/// whole connection: a partially written frame has already corrupted
/// the stream, and the shutdown also unblocks the connection's reader.
fn write_response(
    writer: &Mutex<TcpStream>,
    frame: &ResponseFrame,
    metrics: &FrontendMetrics,
) {
    let code = match &frame.body {
        ResponseBody::Logits { .. } => WireCode::Ok,
        ResponseBody::Error { code, .. } => *code,
    };
    metrics.record_response(code);
    let bytes = encode_response(frame);
    use std::io::Write;
    let mut w = writer.lock().unwrap();
    if w.write_all(&bytes).is_err() {
        let _ = w.shutdown(std::net::Shutdown::Both);
    }
}

/// Serialize one stats (metrics-scrape) response.  Deliberately does
/// NOT go through [`FrontendMetrics::record_response`]: stats replies
/// are observability traffic, and keeping them out of the per-code
/// counters preserves `sum(responses) == requests + protocol_errors`.
fn write_stats(writer: &Mutex<TcpStream>, request_id: u64, text: &str) {
    let bytes = encode_stats_response(request_id, text);
    use std::io::Write;
    let mut w = writer.lock().unwrap();
    if w.write_all(&bytes).is_err() {
        let _ = w.shutdown(std::net::Shutdown::Both);
    }
}

fn error_frame(request_id: u64, code: WireCode, message: String) -> ResponseFrame {
    ResponseFrame {
        request_id,
        latency_us: 0,
        body: ResponseBody::Error { code, message },
    }
}

fn handle_connection(
    stream: TcpStream,
    pipeline: Arc<NativePipeline>,
    gate: Arc<WarmupGate>,
    metrics: Arc<FrontendMetrics>,
    max_inflight: usize,
    stop: Arc<AtomicBool>,
) {
    metrics.connection_opened();
    // SO_SNDTIMEO is per socket (shared by the dup'd fds), so one call
    // bounds every reply write on this connection
    let _ = stream.set_write_timeout(Some(WRITE_STALL_LIMIT));
    let writer = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(_) => {
            metrics.connection_closed();
            return;
        }
    };
    let mut reader = stream;
    let inflight = Arc::new(Inflight::default());
    let tracer = pipeline.tracer().cloned();

    loop {
        let req = match read_incoming(&mut reader) {
            Ok(Some(IncomingFrame::Infer(req))) => req,
            Ok(Some(IncomingFrame::Stats { request_id })) => {
                // a scrape must work while the server warms up or
                // saturates: stats frames bypass the slow-start gate
                // and the inflight cap, and stay out of the traffic
                // counters they report (requests == infer frames;
                // per-code responses count only infer replies)
                metrics.record_stats_request();
                let text = pipeline.registry().render();
                write_stats(&writer, request_id, &text);
                continue;
            }
            Ok(None) => break, // clean close between frames
            Err(FrameError::Protocol { error, request_id }) => {
                // a truncated read during our own drain is the drain,
                // not client abuse: report `shutdown`, leave the abuse
                // counter alone
                if stop.load(Ordering::Relaxed) {
                    write_response(
                        &writer,
                        &error_frame(
                            request_id.unwrap_or(0),
                            WireCode::Shutdown,
                            "server is shutting down".to_string(),
                        ),
                        &metrics,
                    );
                    break;
                }
                // a broken frame poisons the stream: answer (addressed
                // to the offending id when the header got that far,
                // else id 0) and close — but never panic or take the
                // acceptor down with us
                metrics.record_protocol_error();
                write_response(
                    &writer,
                    &error_frame(request_id.unwrap_or(0), WireCode::Protocol, error.to_string()),
                    &metrics,
                );
                break;
            }
            Err(FrameError::Io(_)) => break,
        };
        metrics.record_request();

        if !gate.is_warm(&pipeline) {
            write_response(
                &writer,
                &error_frame(
                    req.request_id,
                    WireCode::WarmingUp,
                    "exploded-map cache warming up; retry shortly".to_string(),
                ),
                &metrics,
            );
            continue;
        }

        let deadline = (req.deadline_budget_us > 0)
            .then(|| Instant::now() + Duration::from_micros(req.deadline_budget_us));
        let mut serve_req = ServeRequest::new(req.payload).with_request_id(req.request_id);
        serve_req.deadline = deadline;

        // per-connection in-flight bound: stop reading frames (TCP
        // backpressure) rather than buffering unbounded waiters
        inflight.inc_below(max_inflight);
        match pipeline.try_submit_request(serve_req) {
            Ok(rx) => {
                let writer = writer.clone();
                let metrics = metrics.clone();
                let inflight = inflight.clone();
                let tracer = tracer.clone();
                let request_id = req.request_id;
                std::thread::spawn(move || {
                    let mut traced = false;
                    let frame = match rx.recv() {
                        Ok(Ok(resp)) => {
                            traced = resp.traced;
                            ResponseFrame {
                                request_id,
                                latency_us: resp.latency.as_micros().min(u64::MAX as u128) as u64,
                                body: ResponseBody::Logits {
                                    predicted: resp.predicted.min(u32::MAX as usize) as u32,
                                    logits: resp.logits,
                                },
                            }
                        }
                        Ok(Err(e)) => {
                            let code = e
                                .downcast_ref::<ServeError>()
                                .map(WireCode::from_serve_error)
                                .unwrap_or(WireCode::Internal);
                            error_frame(request_id, code, e.to_string())
                        }
                        Err(_) => error_frame(
                            request_id,
                            WireCode::Internal,
                            "serving worker lost before reply".to_string(),
                        ),
                    };
                    let write_started = Instant::now();
                    write_response(&writer, &frame, &metrics);
                    // the sixth (and last) span of a sampled request
                    if traced {
                        if let Some(t) = &tracer {
                            t.span(request_id, "socket-write", write_started, Instant::now());
                        }
                    }
                    inflight.dec();
                });
            }
            Err(e) => {
                inflight.dec();
                write_response(
                    &writer,
                    &error_frame(req.request_id, WireCode::from_serve_error(&e), e.to_string()),
                    &metrics,
                );
            }
        }
    }

    // let every in-flight reply land on the wire before closing
    inflight.wait_zero();
    close_connection(reader);
    metrics.connection_closed();
}

/// Close a connection without racing the peer's final read: FIN the
/// write side (the acceptor's tracking clone keeps the fd alive, so an
/// explicit shutdown is what actually ends the stream), then drain a
/// bounded amount of unread input — closing with bytes still queued
/// would RST the socket and could discard the error response we just
/// sent.
fn close_connection(stream: TcpStream) {
    use std::io::Read;
    let _ = stream.shutdown(std::net::Shutdown::Write);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
    let mut stream = stream;
    let mut buf = [0u8; 4096];
    let mut budget: usize = 256 * 1024;
    while budget > 0 {
        match stream.read(&mut buf) {
            Ok(0) | Err(_) => break,
            Ok(n) => budget = budget.saturating_sub(n),
        }
    }
    let _ = stream.shutdown(std::net::Shutdown::Both);
}
