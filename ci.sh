#!/usr/bin/env bash
# CI for the rust crate: build, test, format, lint.
# Mirrors the tier-1 verify (`cargo build --release && cargo test -q`)
# and adds fmt/clippy when those components are installed.
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo doc --no-deps (rustdoc warnings are errors) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== serve-smoke (native engine, no artifacts needed) =="
# start the native server, push a handful of synthetic JPEGs through it,
# assert non-empty logits came back; budget well under 30 s
SMOKE_OUT=$(./target/release/repro serve --engine native --mode sparse --requests 6 \
    --quality 75 --decode-workers 2 --compute-workers 2 --max-batch 4)
echo "$SMOKE_OUT"
echo "$SMOKE_OUT" | grep -q "logit classes: 10" \
    || { echo "serve-smoke FAILED: no logits"; exit 1; }
echo "$SMOKE_OUT" | grep -q "requests=6" \
    || { echo "serve-smoke FAILED: wrong request count"; exit 1; }

echo "== sparse-resident-smoke (activations stay sparse between layers) =="
# the resident kernel must serve the same traffic and report per-layer
# nonzero fractions through the pipeline metrics
RESIDENT_OUT=$(./target/release/repro serve --engine native --mode sparse-resident \
    --requests 6 --quality 75 --decode-workers 2 --compute-workers 2 --max-batch 4)
echo "$RESIDENT_OUT"
echo "$RESIDENT_OUT" | grep -q "logit classes: 10" \
    || { echo "sparse-resident-smoke FAILED: no logits"; exit 1; }
echo "$RESIDENT_OUT" | grep -q "requests=6" \
    || { echo "sparse-resident-smoke FAILED: wrong request count"; exit 1; }
echo "$RESIDENT_OUT" | grep -q "nonzero fraction:" \
    || { echo "sparse-resident-smoke FAILED: no per-layer sparsity"; exit 1; }

echo "== plan-smoke (execution-graph API: one topology, three executors) =="
# `repro exp ablation` runs the plan-executor rows natively (no
# artifacts needed); all three execution strategies must show up
PLAN_OUT=$(./target/release/repro exp ablation --iters 1 --batch 6)
echo "$PLAN_OUT"
for row in "plan dense-kernel" "plan sparse-kernel" "plan sparse-resident"; do
    echo "$PLAN_OUT" | grep -q "$row" \
        || { echo "plan-smoke FAILED: missing row '$row'"; exit 1; }
done
echo "$PLAN_OUT" | grep -q "bit-identical: yes" \
    || { echo "plan-smoke FAILED: sparse vs resident not bit-identical"; exit 1; }

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check
else
    echo "== cargo fmt not installed; skipping =="
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy -D warnings =="
    cargo clippy --all-targets -- -D warnings
else
    echo "== cargo clippy not installed; skipping =="
fi

echo "CI OK"
