//! The TCP acceptor + connection worker pool over a serving backend.
//!
//! One thread accepts; each connection gets a worker thread that parses
//! request frames and feeds [`ServeBackend::submit_with_sink`].  Replies
//! flow through a fixed **reply-pump pool**: whichever pipeline worker
//! finishes a request runs its completion sink, which encodes nothing
//! and blocks on nothing — it stages a [`Completion`] onto one bounded
//! queue, and a handful of pump threads drain that queue back to the
//! mutex-serialized write halves.  Before this PR every in-flight
//! request parked its own short-lived waiter thread; under a
//! multi-connection burst that meant hundreds of concurrent threads
//! doing nothing but blocking on `recv`.  Now thread count is fixed
//! regardless of in-flight depth, and responses still stream back
//! **out of order** — the request id in the frame header is the only
//! correlation.  Everything is `std::net` + `std::thread`; no async
//! runtime.
//!
//! Per-connection flow control, in the order a frame meets it:
//!
//! 1. **Token bucket** (`rate_limit`/`rate_burst`, off by default) —
//!    each request spends `cost` tokens (header byte 21, 0 reads as 1);
//!    an empty bucket answers the typed [`WireCode::RateLimited`]
//!    without touching the pipeline.
//! 2. **Warmup gate** — see below.
//! 3. **In-flight cap** — at most `max_inflight` submitted requests may
//!    be awaiting replies; past that the reader stops pulling frames
//!    off the socket, which backpressures the client through TCP — on
//!    top of the pipeline's own bounded admission queue, whose overflow
//!    surfaces as the typed [`WireCode::QueueFull`] response.
//!
//! ## Slow start, per shard
//!
//! A freshly started server has an empty per-qvec `ExplodedModel` cache;
//! the first batch of each quant table pays a seconds-long precompute.
//! The gate is **per shard**: a request is admitted once the shard that
//! *owns its quant table* (via [`ServeBackend::warm_shard`], which peeks
//! the DQT segment without decoding) has served `warmup_batches` compute
//! batches; until then it is rejected with the typed
//! [`WireCode::WarmingUp`] code instead of queueing behind the cliff.
//! This fixes the PR-7 global gate, where one warm replica opened the
//! door for qvecs whose owning replica was still cold.  In-process
//! callers (the warmup driver in `repro serve --listen`) bypass the
//! gate, which is what lets the caches warm in the first place.  Each
//! shard's gate is sticky: once open it never closes.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::serving::error::ServeError;
use crate::serving::metrics::FrontendMetrics;
use crate::serving::pipeline::{ReplySink, ServeRequest};
use crate::serving::queue::{bounded_with_gauge, BoundedReceiver, BoundedSender};
use crate::serving::ServeBackend;
use crate::telemetry::Tracer;

use super::protocol::{
    encode_response, encode_stats_response, read_incoming, FrameError, IncomingFrame,
    ResponseBody, ResponseFrame, WireCode,
};

/// Threads draining the completion queue.  Writes are short (one frame
/// onto a kernel send buffer) so a small fixed pool keeps up; a client
/// that stops reading stalls one pump thread for at most
/// [`WRITE_STALL_LIMIT`] before its connection is declared dead.
const REPLY_PUMP_THREADS: usize = 4;
/// Completion queue capacity.  Full is backpressure: a compute worker
/// delivering a reply blocks until a pump drains — bounded, like every
/// other queue in the pipeline.
const COMPLETION_QUEUE_CAP: usize = 1024;

/// Socket front end settings (`[serve] listen_addr` / `warmup_batches`
/// / `rate_limit`; CLI flags override).
#[derive(Clone, Debug)]
pub struct FrontendConfig {
    /// Address to bind (`"127.0.0.1:0"` = loopback, ephemeral port).
    pub listen_addr: String,
    /// Compute batches a shard must have served before socket traffic
    /// routed to it is admitted; `0` disables the slow-start gate.
    pub warmup_batches: u64,
    /// Per-connection cap on submitted-but-unanswered requests; past it
    /// the reader stops pulling frames (TCP backpressure).
    pub max_inflight: usize,
    /// Per-connection token-bucket refill rate in tokens/second;
    /// `0` disables rate limiting.
    pub rate_limit: usize,
    /// Token-bucket burst capacity; `0` defaults to `rate_limit`.
    pub rate_burst: usize,
}

impl Default for FrontendConfig {
    fn default() -> Self {
        FrontendConfig {
            listen_addr: "127.0.0.1:0".to_string(),
            warmup_batches: 0,
            max_inflight: 64,
            rate_limit: 0,
            rate_burst: 0,
        }
    }
}

/// Per-connection token bucket.  Owned by the connection's reader
/// thread (no sharing, no locks): tokens refill continuously at `rate`
/// per second up to `burst`, and each admitted request spends its
/// declared cost (header byte 21, `0` reads as 1).
struct TokenBucket {
    rate: f64,
    burst: f64,
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    /// `None` when `rate` is 0 (limiting disabled).
    fn new(rate: usize, burst: usize) -> Option<TokenBucket> {
        if rate == 0 {
            return None;
        }
        let burst = if burst == 0 { rate } else { burst } as f64;
        Some(TokenBucket {
            rate: rate as f64,
            burst,
            // a fresh connection starts with a full bucket
            tokens: burst,
            last: Instant::now(),
        })
    }

    fn admit(&mut self, cost: u8) -> bool {
        let now = Instant::now();
        let refill = self.rate * now.duration_since(self.last).as_secs_f64();
        self.tokens = (self.tokens + refill).min(self.burst);
        self.last = now;
        let cost = cost.max(1) as f64;
        if self.tokens >= cost {
            self.tokens -= cost;
            true
        } else {
            false
        }
    }
}

/// Sticky slow-start gate, one flag per shard.
///
/// [`ServeBackend::warm_shard`] maps a payload to its owning shard and
/// that shard's served-batch count by peeking the JPEG's DQT segment —
/// no entropy decode, no admission.  Unsharded backends report shard 0
/// for everything, reproducing the old global gate exactly.
struct WarmupGate {
    need: u64,
    warmed: Vec<AtomicBool>,
}

impl WarmupGate {
    fn new(need: u64, shards: usize) -> WarmupGate {
        WarmupGate {
            need,
            warmed: (0..shards.max(1)).map(|_| AtomicBool::new(need == 0)).collect(),
        }
    }

    fn is_warm(&self, backend: &dyn ServeBackend, payload: &[u8]) -> bool {
        let (shard, batches) = backend.warm_shard(payload);
        let flag = &self.warmed[shard.min(self.warmed.len() - 1)];
        if flag.load(Ordering::Relaxed) {
            return true;
        }
        if batches >= self.need {
            flag.store(true, Ordering::Relaxed);
            return true;
        }
        false
    }
}

/// Counting gauge with waiters: bounds per-connection in-flight
/// requests and lets the connection worker drain before closing.
#[derive(Default)]
struct Inflight {
    count: Mutex<usize>,
    changed: Condvar,
}

impl Inflight {
    fn inc_below(&self, cap: usize) {
        let mut n = self.count.lock().unwrap();
        while *n >= cap.max(1) {
            n = self.changed.wait(n).unwrap();
        }
        *n += 1;
    }

    fn dec(&self) {
        let mut n = self.count.lock().unwrap();
        *n -= 1;
        self.changed.notify_all();
    }

    fn wait_zero(&self) {
        let mut n = self.count.lock().unwrap();
        while *n > 0 {
            n = self.changed.wait(n).unwrap();
        }
    }
}

/// One finished request on its way back to the wire: the encoded-ready
/// frame plus everything a pump thread needs to write it and settle the
/// connection's in-flight accounting.
struct Completion {
    frame: ResponseFrame,
    writer: Arc<Mutex<TcpStream>>,
    inflight: Arc<Inflight>,
    traced: bool,
    request_id: u64,
}

/// The reply-pump pool: the frontend's half of the completion queue.
struct ReplyPump {
    /// Dropped during shutdown *after* connection workers join, so
    /// every staged completion drains before the pumps exit.
    tx: Option<BoundedSender<Completion>>,
    handles: Vec<JoinHandle<()>>,
}

/// A running socket front end.  Dropping (or [`SocketFrontend::shutdown`])
/// stops the acceptor, closes every connection, and joins all workers;
/// the backend itself is left running (shut it down after).
pub struct SocketFrontend {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<(TcpStream, JoinHandle<()>)>>>,
    pump: Option<ReplyPump>,
    /// Per-connection / per-wire-code counters.
    pub metrics: Arc<FrontendMetrics>,
}

impl SocketFrontend {
    /// Bind `cfg.listen_addr` and start accepting.  Fails fast when the
    /// address cannot be bound (taken port, bad syntax).  The backend is
    /// a single [`crate::serving::NativePipeline`] or a
    /// [`crate::serving::ShardedCoordinator`] — the listener is
    /// identical over both.
    pub fn start(
        backend: Arc<dyn ServeBackend>,
        cfg: FrontendConfig,
    ) -> anyhow::Result<SocketFrontend> {
        let listener = TcpListener::bind(&cfg.listen_addr)
            .map_err(|e| anyhow::anyhow!("bind {}: {e}", cfg.listen_addr))?;
        let local_addr = listener.local_addr()?;
        // non-blocking accept so the stop flag is honored promptly
        listener.set_nonblocking(true)?;
        // frontend counters live in the backend's registry, so one
        // Stats scrape covers both layers (and every shard)
        let metrics = Arc::new(FrontendMetrics::register(backend.registry()));
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<(TcpStream, JoinHandle<()>)>>> =
            Arc::new(Mutex::new(Vec::new()));
        let gate = Arc::new(WarmupGate::new(cfg.warmup_batches, backend.shard_count()));
        let max_inflight = cfg.max_inflight.max(1);
        let (rate_limit, rate_burst) = (cfg.rate_limit, cfg.rate_burst);

        // the completion queue + pump pool; its depth gauge joins the
        // admission/decoded families so a scrape sees write backlog too
        let (pump_tx, pump_rx) = bounded_with_gauge::<Completion>(
            COMPLETION_QUEUE_CAP,
            backend.registry().gauge(
                "jd_queue_depth",
                "live items in a pipeline queue",
                &[("queue", "completion")],
            ),
        );
        let tracer = backend.tracer().cloned();
        let pump_handles: Vec<JoinHandle<()>> = (0..REPLY_PUMP_THREADS)
            .map(|_| {
                let rx = pump_rx.clone();
                let metrics = metrics.clone();
                let tracer = tracer.clone();
                std::thread::spawn(move || reply_pump(rx, metrics, tracer))
            })
            .collect();

        let acceptor = {
            let stop = stop.clone();
            let conns = conns.clone();
            let metrics = metrics.clone();
            let pump_tx = pump_tx.clone();
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            let _ = stream.set_nodelay(true);
                            let _ = stream.set_nonblocking(false);
                            let Ok(track) = stream.try_clone() else { continue };
                            let backend = backend.clone();
                            let gate = gate.clone();
                            let metrics = metrics.clone();
                            let stop = stop.clone();
                            let pump_tx = pump_tx.clone();
                            let handle = std::thread::spawn(move || {
                                handle_connection(
                                    stream,
                                    backend,
                                    gate,
                                    metrics,
                                    pump_tx,
                                    (rate_limit, rate_burst),
                                    max_inflight,
                                    stop,
                                )
                            });
                            let mut guard = conns.lock().unwrap();
                            // reap finished workers so long-lived servers
                            // don't accumulate dead handles
                            let mut i = 0;
                            while i < guard.len() {
                                if guard[i].1.is_finished() {
                                    let (_, h) = guard.swap_remove(i);
                                    let _ = h.join();
                                } else {
                                    i += 1;
                                }
                            }
                            guard.push((track, handle));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                        // a bad accept must never wedge the acceptor
                        Err(_) => std::thread::sleep(Duration::from_millis(5)),
                    }
                }
            })
        };

        Ok(SocketFrontend {
            local_addr,
            stop,
            acceptor: Some(acceptor),
            conns,
            pump: Some(ReplyPump { tx: Some(pump_tx), handles: pump_handles }),
            metrics,
        })
    }

    /// The bound address (resolves the port when `:0` was requested).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Stop accepting, close every connection, join all workers.
    pub fn shutdown(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        let conns = std::mem::take(&mut *self.conns.lock().unwrap());
        for (stream, handle) in conns {
            // unblock the reader but leave the write half open —
            // shutdown applies socket-wide across the dup'd fds, and
            // in-flight replies still have to flush (the backend is
            // still up); the worker FINs the write side itself once
            // its inflight count drains to zero
            let _ = stream.shutdown(std::net::Shutdown::Read);
            let _ = handle.join();
        }
        // connection workers joined => every submitted request's
        // completion has been staged AND written (wait_zero held the
        // worker until the pumps finished its replies).  Dropping the
        // last sender ends the pump loops.
        if let Some(mut pump) = self.pump.take() {
            drop(pump.tx.take());
            for h in pump.handles.drain(..) {
                let _ = h.join();
            }
        }
    }
}

impl Drop for SocketFrontend {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

/// How long a reply write may block before the connection is declared
/// dead.  A client that stops reading fills its TCP receive window and
/// would otherwise park a pump thread in `write_all` forever — pinning
/// the completion queue, the connection worker's drain, and ultimately
/// [`SocketFrontend::shutdown`].
const WRITE_STALL_LIMIT: Duration = Duration::from_secs(30);

/// Drain the completion queue: write each staged frame onto its
/// connection's shared write half, close sampled requests' trace with
/// the `socket-write` span, and settle the in-flight count.  Exits when
/// every sender is gone and the queue is drained — i.e. after the last
/// connection worker has joined.
fn reply_pump(
    rx: Arc<BoundedReceiver<Completion>>,
    metrics: Arc<FrontendMetrics>,
    tracer: Option<Arc<Tracer>>,
) {
    while let Some(c) = rx.recv() {
        let write_started = Instant::now();
        write_response(&c.writer, &c.frame, &metrics);
        // the sixth (and last) span of a sampled request
        if c.traced {
            if let Some(t) = &tracer {
                t.span(c.request_id, "socket-write", write_started, Instant::now());
            }
        }
        c.inflight.dec();
    }
}

/// Serialize one response frame onto the shared write half.  A write
/// error (peer gone, or stalled past [`WRITE_STALL_LIMIT`]) kills the
/// whole connection: a partially written frame has already corrupted
/// the stream, and the shutdown also unblocks the connection's reader.
fn write_response(
    writer: &Mutex<TcpStream>,
    frame: &ResponseFrame,
    metrics: &FrontendMetrics,
) {
    let code = match &frame.body {
        ResponseBody::Logits { .. } => WireCode::Ok,
        ResponseBody::Error { code, .. } => *code,
    };
    metrics.record_response(code);
    let bytes = encode_response(frame);
    use std::io::Write;
    let mut w = writer.lock().unwrap();
    if w.write_all(&bytes).is_err() {
        let _ = w.shutdown(std::net::Shutdown::Both);
    }
}

/// Serialize one stats (metrics-scrape) response.  Deliberately does
/// NOT go through [`FrontendMetrics::record_response`]: stats replies
/// are observability traffic, and keeping them out of the per-code
/// counters preserves `sum(responses) == requests + protocol_errors`.
fn write_stats(writer: &Mutex<TcpStream>, request_id: u64, text: &str) {
    let bytes = encode_stats_response(request_id, text);
    use std::io::Write;
    let mut w = writer.lock().unwrap();
    if w.write_all(&bytes).is_err() {
        let _ = w.shutdown(std::net::Shutdown::Both);
    }
}

fn error_frame(request_id: u64, code: WireCode, message: String) -> ResponseFrame {
    ResponseFrame {
        request_id,
        latency_us: 0,
        body: ResponseBody::Error { code, message },
    }
}

/// Build the wire frame for a finished request (shared by the sink
/// path and the submission-error path's `Ok` twin).
fn response_frame(
    request_id: u64,
    result: anyhow::Result<crate::coordinator::server::InferResponse>,
) -> (ResponseFrame, bool) {
    match result {
        Ok(resp) => {
            let traced = resp.traced;
            (
                ResponseFrame {
                    request_id,
                    latency_us: resp.latency.as_micros().min(u64::MAX as u128) as u64,
                    body: ResponseBody::Logits {
                        predicted: resp.predicted.min(u32::MAX as usize) as u32,
                        logits: resp.logits,
                    },
                },
                traced,
            )
        }
        Err(e) => {
            let code = e
                .downcast_ref::<ServeError>()
                .map(WireCode::from_serve_error)
                .unwrap_or(WireCode::Internal);
            (error_frame(request_id, code, e.to_string()), false)
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_connection(
    stream: TcpStream,
    backend: Arc<dyn ServeBackend>,
    gate: Arc<WarmupGate>,
    metrics: Arc<FrontendMetrics>,
    pump_tx: BoundedSender<Completion>,
    (rate_limit, rate_burst): (usize, usize),
    max_inflight: usize,
    stop: Arc<AtomicBool>,
) {
    metrics.connection_opened();
    // SO_SNDTIMEO is per socket (shared by the dup'd fds), so one call
    // bounds every reply write on this connection
    let _ = stream.set_write_timeout(Some(WRITE_STALL_LIMIT));
    let writer = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(_) => {
            metrics.connection_closed();
            return;
        }
    };
    let mut reader = stream;
    let inflight = Arc::new(Inflight::default());
    let tracer = backend.tracer().cloned();
    let mut bucket = TokenBucket::new(rate_limit, rate_burst);

    loop {
        let req = match read_incoming(&mut reader) {
            Ok(Some(IncomingFrame::Infer(req))) => req,
            Ok(Some(IncomingFrame::Stats { request_id })) => {
                // a scrape must work while the server warms up,
                // saturates, or rate-limits: stats frames bypass the
                // bucket, the slow-start gate and the inflight cap, and
                // stay out of the traffic counters they report
                // (requests == infer frames; per-code responses count
                // only infer replies)
                metrics.record_stats_request();
                let text = backend.registry().render();
                write_stats(&writer, request_id, &text);
                continue;
            }
            Ok(None) => break, // clean close between frames
            Err(FrameError::Protocol { error, request_id }) => {
                // a truncated read during our own drain is the drain,
                // not client abuse: report `shutdown`, leave the abuse
                // counter alone
                if stop.load(Ordering::Relaxed) {
                    write_response(
                        &writer,
                        &error_frame(
                            request_id.unwrap_or(0),
                            WireCode::Shutdown,
                            "server is shutting down".to_string(),
                        ),
                        &metrics,
                    );
                    break;
                }
                // a broken frame poisons the stream: answer (addressed
                // to the offending id when the header got that far,
                // else id 0) and close — but never panic or take the
                // acceptor down with us
                metrics.record_protocol_error();
                write_response(
                    &writer,
                    &error_frame(request_id.unwrap_or(0), WireCode::Protocol, error.to_string()),
                    &metrics,
                );
                break;
            }
            Err(FrameError::Io(_)) => break,
        };
        metrics.record_request();

        // the token bucket sits in front of the pipeline: a limited
        // request costs the server one frame parse and one small write,
        // never queue space or decode time
        if let Some(b) = bucket.as_mut() {
            if !b.admit(req.cost) {
                metrics.rate_limited.inc();
                write_response(
                    &writer,
                    &error_frame(
                        req.request_id,
                        WireCode::RateLimited,
                        "connection token bucket empty; slow down and retry".to_string(),
                    ),
                    &metrics,
                );
                continue;
            }
        }

        if !gate.is_warm(backend.as_ref(), &req.payload) {
            write_response(
                &writer,
                &error_frame(
                    req.request_id,
                    WireCode::WarmingUp,
                    "exploded-map cache warming up; retry shortly".to_string(),
                ),
                &metrics,
            );
            continue;
        }

        let deadline = (req.deadline_budget_us > 0)
            .then(|| Instant::now() + Duration::from_micros(req.deadline_budget_us));
        let request_id = req.request_id;
        let mut serve_req = ServeRequest::new(req.payload).with_request_id(request_id);
        serve_req.deadline = deadline;

        // per-connection in-flight bound: stop reading frames (TCP
        // backpressure) rather than staging unbounded completions
        inflight.inc_below(max_inflight);
        let sink = {
            let writer = writer.clone();
            let inflight = inflight.clone();
            let pump_tx = pump_tx.clone();
            let metrics = metrics.clone();
            let tracer = tracer.clone();
            ReplySink::new(move |result| {
                let (frame, traced) = response_frame(request_id, result);
                let completion =
                    Completion { frame, writer, inflight, traced, request_id };
                if let Err(c) = pump_tx.send(completion) {
                    // pump already gone (shutdown tail): write inline so
                    // the admitted request still gets its reply
                    let write_started = Instant::now();
                    write_response(&c.writer, &c.frame, &metrics);
                    if c.traced {
                        if let Some(t) = &tracer {
                            t.span(c.request_id, "socket-write", write_started, Instant::now());
                        }
                    }
                    c.inflight.dec();
                }
            })
        };
        if let Err(e) = backend.submit_with_sink(serve_req, sink) {
            // the sink was disarmed by the rejection: the reply is ours
            inflight.dec();
            write_response(
                &writer,
                &error_frame(request_id, WireCode::from_serve_error(&e), e.to_string()),
                &metrics,
            );
        }
    }

    // let every in-flight reply land on the wire before closing: the
    // pump dec()s as it writes, so zero means written, not just staged
    inflight.wait_zero();
    close_connection(reader);
    metrics.connection_closed();
}

/// Close a connection without racing the peer's final read: FIN the
/// write side (the acceptor's tracking clone keeps the fd alive, so an
/// explicit shutdown is what actually ends the stream), then drain a
/// bounded amount of unread input — closing with bytes still queued
/// would RST the socket and could discard the error response we just
/// sent.
fn close_connection(stream: TcpStream) {
    use std::io::Read;
    let _ = stream.shutdown(std::net::Shutdown::Write);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
    let mut stream = stream;
    let mut buf = [0u8; 4096];
    let mut budget: usize = 256 * 1024;
    while budget > 0 {
        match stream.read(&mut buf) {
            Ok(0) | Err(_) => break,
            Ok(n) => budget = budget.saturating_sub(n),
        }
    }
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_bucket_spends_refills_and_caps_at_burst() {
        let mut b = TokenBucket::new(1000, 2).expect("rate > 0 builds a bucket");
        assert!(b.admit(1), "fresh bucket starts full");
        assert!(b.admit(0), "cost 0 reads as 1");
        // burst 2 spent with (at most) a trivial refill in between:
        // force the empty state deterministically, then verify refill
        b.tokens = 0.0;
        b.last = Instant::now();
        assert!(!b.admit(1), "empty bucket rejects");
        // 1000 tokens/s refills well past burst in 10ms — and is capped
        std::thread::sleep(Duration::from_millis(10));
        assert!(b.admit(2), "refill reaches burst");
        assert!(b.tokens < 1.0, "burst cap held: {}", b.tokens);
    }

    #[test]
    fn rate_zero_disables_the_bucket() {
        assert!(TokenBucket::new(0, 64).is_none());
    }

    #[test]
    fn burst_zero_defaults_to_rate() {
        let b = TokenBucket::new(7, 0).unwrap();
        assert_eq!(b.burst, 7.0);
        assert_eq!(b.tokens, 7.0);
    }

    #[test]
    fn warmup_gate_tracks_shards_independently_and_sticks() {
        struct TwoShards;
        impl ServeBackend for TwoShards {
            fn try_submit_request(
                &self,
                _req: ServeRequest,
            ) -> Result<
                std::sync::mpsc::Receiver<
                    anyhow::Result<crate::coordinator::server::InferResponse>,
                >,
                ServeError,
            > {
                Err(ServeError::ShuttingDown)
            }
            fn submit_with_sink(
                &self,
                _req: ServeRequest,
                _sink: ReplySink,
            ) -> Result<(), ServeError> {
                Err(ServeError::ShuttingDown)
            }
            fn registry(&self) -> &Arc<crate::telemetry::Registry> {
                unreachable!("gate test never scrapes")
            }
            fn tracer(&self) -> Option<&Arc<Tracer>> {
                None
            }
            fn shard_count(&self) -> usize {
                2
            }
            fn warm_shard(&self, payload: &[u8]) -> (usize, u64) {
                // payload[0] = shard, payload[1] = batches served
                (payload[0] as usize, payload[1] as u64)
            }
            fn warm(&self, _quality: u8) {}
        }
        let be = TwoShards;
        let gate = WarmupGate::new(2, be.shard_count());
        assert!(!gate.is_warm(&be, &[0, 0]), "shard 0 cold");
        assert!(gate.is_warm(&be, &[1, 5]), "shard 1 warm");
        assert!(!gate.is_warm(&be, &[0, 1]), "shard 1's warmth must not open shard 0");
        assert!(gate.is_warm(&be, &[0, 2]), "shard 0 crosses its own threshold");
        assert!(gate.is_warm(&be, &[0, 0]), "sticky: once open, stays open");
    }

    #[test]
    fn warmup_gate_zero_need_is_open_everywhere() {
        let gate = WarmupGate::new(0, 3);
        for flag in &gate.warmed {
            assert!(flag.load(Ordering::Relaxed));
        }
    }
}
