//! Perf probe: the sparse exploded-conv engine ablation, the
//! dense-boundary vs sparse-resident forward ablation, the
//! plan-executor ablation (the three execution strategies over the
//! single topology) and the prune-epsilon curve (native, always run) +
//! per-stage timing of both PJRT serving pipelines (when artifacts are
//! present).  Used by the EXPERIMENTS.md §Perf iteration log; emits
//! `BENCH_PR4.json` (throughput rows + per-layer nonzero fractions +
//! per-op plan timings) so successive PRs have a perf trajectory.
//!
//! Run: `cargo run --release --example perf_probe`
//! Env: PP_QUALITY (50), PP_BATCH (40), PP_COUT (16), PP_ITERS (5),
//!      PP_PASSES (2), PP_THREADS (4), PP_OUT (BENCH_PR4.json)

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use jpegdomain::bench_harness as bh;
use jpegdomain::coordinator::router::{Route, Router};
use jpegdomain::data::{Dataset, Split, SynthKind};
use jpegdomain::jpeg::codec;
use jpegdomain::jpeg_domain::network::ExplodedModel;
use jpegdomain::jpeg_domain::relu::Method;
use jpegdomain::json::Json;
use jpegdomain::params::{ModelConfig, ParamSet};
use jpegdomain::runtime::{Engine, Session};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn time_us(iters: usize, mut f: impl FnMut()) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() * 1e6 / iters as f64
}

/// The native sparse engine probe: kernel ablation + end-to-end
/// inference thread sweep.  No artifacts required.
fn native_probe(report: &mut BTreeMap<String, Json>) -> anyhow::Result<()> {
    let quality = env_usize("PP_QUALITY", 50) as u8;
    let batch = env_usize("PP_BATCH", 40);
    let iters = env_usize("PP_ITERS", 5);
    let threads = env_usize("PP_THREADS", 4);

    // -- kernel-level: dense vs sparse vs threaded --------------------------
    let r = bh::sparse_conv_ablation(quality, batch, env_usize("PP_COUT", 16), threads, iters);
    bh::throughput::print_sparse_conv(&r);
    let mut conv = BTreeMap::new();
    conv.insert("quality".into(), num(r.quality as f64));
    conv.insert("batch".into(), num(r.batch as f64));
    conv.insert("cout".into(), num(r.cout as f64));
    conv.insert("threads".into(), num(r.threads as f64));
    conv.insert("density".into(), num(r.density));
    conv.insert("dense_blocks_per_sec".into(), num(r.dense_blocks_per_sec));
    conv.insert("sparse_blocks_per_sec".into(), num(r.sparse_blocks_per_sec));
    conv.insert(
        "threaded_blocks_per_sec".into(),
        num(r.threaded_blocks_per_sec),
    );
    conv.insert("sparse_speedup_vs_dense".into(), num(r.sparse_speedup));
    conv.insert("thread_scaling".into(), num(r.thread_scaling));
    conv.insert(
        "max_abs_diff_vs_dcc".into(),
        num(r.max_abs_diff_vs_dcc as f64),
    );
    report.insert("sparse_conv".into(), Json::Obj(conv));

    // -- end-to-end: native exploded inference, 1 thread vs N ---------------
    let cfg = ModelConfig::preset("mnist").expect("preset");
    let params = ParamSet::init(&cfg, 0);
    let data = Dataset::synthetic(SynthKind::Mnist, 2, batch.max(40), 3);
    let files = data.jpeg_bytes(Split::Test, quality);
    let qvec = codec::decode_to_coefficients(&files[0].0)?.qvec(0);
    let em = ExplodedModel::precompute(&params, &qvec);
    let passes = env_usize("PP_PASSES", 2);
    let ips1 =
        bh::native_sparse_inference_throughput(&cfg, &params, &em, &files, batch, passes, 1)?;
    let ips_n = bh::native_sparse_inference_throughput(
        &cfg, &params, &em, &files, batch, passes, threads,
    )?;
    println!(
        "\nnative sparse inference: {ips1:.1} img/s @ 1 thread | {ips_n:.1} img/s @ {threads} \
         threads ({:.2}x)",
        ips_n / ips1
    );
    let mut inf = BTreeMap::new();
    inf.insert("quality".into(), num(quality as f64));
    inf.insert("batch".into(), num(batch as f64));
    inf.insert("threads".into(), num(threads as f64));
    inf.insert("images_per_sec_1_thread".into(), num(ips1));
    inf.insert("images_per_sec_n_threads".into(), num(ips_n));
    inf.insert("thread_scaling".into(), num(ips_n / ips1));
    report.insert("native_inference".into(), Json::Obj(inf));

    // -- tentpole: dense-boundary vs sparse-resident forward ----------------
    let rr = bh::resident_forward_ablation(quality, batch, iters, threads)?;
    bh::throughput::print_resident(&rr);
    let mut res = BTreeMap::new();
    res.insert("quality".into(), num(rr.quality as f64));
    res.insert("batch".into(), num(rr.batch as f64));
    res.insert("threads".into(), num(rr.threads as f64));
    res.insert("input_density".into(), num(rr.input_density));
    res.insert(
        "dense_boundary_images_per_sec".into(),
        num(rr.dense_boundary_images_per_sec),
    );
    res.insert(
        "sparse_resident_images_per_sec".into(),
        num(rr.resident_images_per_sec),
    );
    res.insert("speedup_resident_vs_boundary".into(), num(rr.speedup));
    res.insert("max_abs_diff".into(), num(rr.max_abs_diff as f64));
    let mut layers = BTreeMap::new();
    for (label, d) in &rr.layer_density {
        layers.insert(label.to_string(), num(*d));
    }
    res.insert("layer_nonzero".into(), Json::Obj(layers));
    report.insert("residency".into(), Json::Obj(res));

    // -- plan API: the three executors over the single topology -------------
    let pa = bh::plan_executor_ablation(quality, batch, iters, threads)?;
    bh::throughput::print_plan_ablation(&pa);
    let mut plan = BTreeMap::new();
    plan.insert("quality".into(), num(pa.quality as f64));
    plan.insert("batch".into(), num(pa.batch as f64));
    plan.insert("threads".into(), num(pa.threads as f64));
    plan.insert("input_density".into(), num(pa.input_density));
    plan.insert(
        "sparse_vs_resident_bitwise".into(),
        num(if pa.sparse_vs_resident_bitwise { 1.0 } else { 0.0 }),
    );
    plan.insert("dense_kernel_max_dev".into(), num(pa.dense_kernel_max_dev as f64));
    for row in &pa.rows {
        plan.insert(
            format!("{}_images_per_sec", row.executor.replace('-', "_")),
            num(row.images_per_sec),
        );
    }
    let mut ops = BTreeMap::new();
    for (i, (label, ms)) in pa.op_timings_ms.iter().enumerate() {
        ops.insert(format!("{i:02} {label}"), num(*ms));
    }
    plan.insert("resident_op_ms".into(), Json::Obj(ops));
    report.insert("plan_executors".into(), Json::Obj(plan));

    // -- prune-epsilon curve (the paper's "little to no penalty" knob) ------
    let pr = bh::prune_epsilon_ablation(quality, batch, iters, threads, &[0.0, 1e-4, 1e-3, 1e-2])?;
    bh::throughput::print_prune(&pr);
    let rows: Vec<Json> = pr
        .rows
        .iter()
        .map(|row| {
            let mut o = BTreeMap::new();
            o.insert("epsilon".into(), num(row.epsilon as f64));
            o.insert("images_per_sec".into(), num(row.images_per_sec));
            o.insert("prediction_agreement".into(), num(row.prediction_agreement));
            o.insert("max_logit_dev".into(), num(row.max_logit_dev as f64));
            o.insert("mean_nonzero".into(), num(row.mean_nonzero));
            Json::Obj(o)
        })
        .collect();
    report.insert("prune_epsilon".into(), Json::Arr(rows));
    Ok(())
}

/// The original PJRT pipeline probe; skipped when no artifacts exist.
fn pjrt_probe(engine: Arc<Engine>) -> anyhow::Result<()> {
    for config in ["mnist", "cifar10"] {
        let session = Session::new(engine.clone(), config)?;
        let params = ParamSet::init(&session.cfg, 0);
        let kind = SynthKind::parse(config).unwrap();
        let data = Dataset::synthetic(kind, 2, 40, 3);
        let files = data.jpeg_bytes(Split::Test, 95);
        let batch = 40;

        // rust-side prepare per route
        let sp_router = Router::new(Route::Spatial);
        let jp_router = Router::new(Route::Jpeg);
        let prep_sp = time_us(5, || {
            for (b, _) in &files {
                std::hint::black_box(sp_router.prepare(b).unwrap());
            }
        }) / batch as f64;
        let prep_jp = time_us(5, || {
            for (b, _) in &files {
                std::hint::black_box(jp_router.prepare(b).unwrap());
            }
        }) / batch as f64;

        // batch forwards (inputs prepared once)
        let sp_inputs: Vec<_> = files
            .iter()
            .map(|(b, _)| sp_router.prepare(b).unwrap().input)
            .collect();
        let x = Router::stack(&sp_inputs);
        let jp_prepared: Vec<_> = files
            .iter()
            .map(|(b, _)| jp_router.prepare(b).unwrap())
            .collect();
        let qvec = jp_prepared[0].qvec;
        let coeffs =
            Router::stack(&jp_prepared.iter().map(|p| p.input.clone()).collect::<Vec<_>>());

        // warm
        session.forward_spatial(&params, &x)?;
        session.forward_jpeg_fused(&params, &coeffs, &qvec)?;
        session.forward_jpeg(&params, &coeffs, &qvec, 15, Method::Asm)?;

        let f_sp = time_us(20, || {
            std::hint::black_box(session.forward_spatial(&params, &x).unwrap());
        });
        let f_fused = time_us(20, || {
            std::hint::black_box(
                session.forward_jpeg_fused(&params, &coeffs, &qvec).unwrap(),
            );
        });
        let f_domain = time_us(5, || {
            std::hint::black_box(
                session
                    .forward_jpeg(&params, &coeffs, &qvec, 15, Method::Asm)
                    .unwrap(),
            );
        });

        // batch-1 scaling probe: overhead vs compute
        let sp1: Vec<_> = sp_inputs[..1].to_vec();
        let xb1 = Router::stack(&sp1);
        session.forward_spatial(&params, &xb1)?;
        let f_sp1 = time_us(20, || {
            std::hint::black_box(session.forward_spatial(&params, &xb1).unwrap());
        });
        println!("forward b1: spatial {f_sp1:.0} us (b40/40 = {:.0} us)", f_sp / 40.0);
        println!("\n== {config} (batch {batch}) ==");
        println!(
            "prepare/img:   spatial {prep_sp:.1} us | jpeg {prep_jp:.1} us | delta {:.1} us",
            prep_sp - prep_jp
        );
        println!(
            "forward/batch: spatial {f_sp:.0} us | jpeg-fused {f_fused:.0} us | jpeg-domain {f_domain:.0} us"
        );
        println!(
            "end-to-end/img: spatial {:.1} us | jpeg-fused {:.1} us",
            prep_sp + f_sp / batch as f64,
            prep_jp + f_fused / batch as f64
        );
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let mut report = BTreeMap::new();
    // a native-probe failure must not cost us the JSON or the PJRT probe
    if let Err(e) = native_probe(&mut report) {
        eprintln!("native probe failed: {e}");
    }

    let out = std::env::var("PP_OUT").unwrap_or_else(|_| "BENCH_PR4.json".into());
    std::fs::write(&out, format!("{}\n", Json::Obj(report)))?;
    println!("\nwrote {out}");

    match Engine::new(std::path::Path::new("artifacts")) {
        Ok(engine) => pjrt_probe(Arc::new(engine))?,
        Err(e) => eprintln!("skipping PJRT probe: {e}"),
    }
    Ok(())
}
