//! Deterministic mutation fuzzer for the decode and wire layers.
//!
//! std-only and fully seeded: the same `(iters, seed)` pair visits the
//! same mutated inputs on every platform, so a CI smoke run is
//! reproducible and a reported failure replays exactly.  Seeds come from
//! the [`super::corpus`] fixtures; mutators are the classic byte-level
//! set — bit flips, byte sets, truncation, junk extension, cross-seed
//! splices, chunk deletion/duplication, marker nudges and length-field
//! tweaks — stacked 1..=4 deep per iteration.
//!
//! The contract under test: **every** input either decodes or returns a
//! typed error.  A panic anywhere in `jpeg::decode_to_coefficients` or
//! `protocol::read_incoming` is a bug, and the harness catches and
//! reports it (with the seed/iteration needed to replay) instead of
//! taking the process down.

use super::codec::decode_to_coefficients;
use super::corpus;
use crate::serving::frontend::protocol;
use crate::util::Rng;
use std::io::Cursor;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Aggregate result of one fuzz run.
pub struct FuzzReport {
    pub target: &'static str,
    pub iters: usize,
    /// inputs that decoded / parsed successfully despite mutation
    pub ok: usize,
    /// inputs rejected with a typed error (the expected common case)
    pub typed_err: usize,
    /// replay coordinates of every panic: `(iteration, description)`
    pub panics: Vec<(usize, String)>,
}

impl std::fmt::Display for FuzzReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "fuzz {}: iters={} decoded_ok={} typed_errors={} panics={}",
            self.target,
            self.iters,
            self.ok,
            self.typed_err,
            self.panics.len()
        )
    }
}

/// Apply 1..=4 stacked mutations of `base`, splicing from `donors` when
/// the splice mutator is drawn.
fn mutate(rng: &mut Rng, base: &[u8], donors: &[Vec<u8>]) -> Vec<u8> {
    let mut data = base.to_vec();
    let n_ops = 1 + rng.below(4);
    for _ in 0..n_ops {
        if data.is_empty() {
            data = vec![0u8; 4];
        }
        match rng.below(9) {
            // single bit flip
            0 => {
                let i = rng.below(data.len());
                data[i] ^= 1 << rng.below(8);
            }
            // byte set
            1 => {
                let i = rng.below(data.len());
                data[i] = rng.below(256) as u8;
            }
            // truncate to a prefix
            2 => {
                let keep = rng.below(data.len());
                data.truncate(keep.max(1));
            }
            // extend with junk
            3 => {
                let n = 1 + rng.below(64);
                for _ in 0..n {
                    data.push(rng.below(256) as u8);
                }
            }
            // splice a chunk from another seed input
            4 => {
                let donor = &donors[rng.below(donors.len())];
                if donor.is_empty() {
                    continue;
                }
                let src = rng.below(donor.len());
                let len = (1 + rng.below(48)).min(donor.len() - src);
                let dst = rng.below(data.len());
                let end = (dst + len).min(data.len());
                data.splice(dst..end, donor[src..src + len].iter().copied());
            }
            // delete a chunk
            5 => {
                let start = rng.below(data.len());
                let len = (1 + rng.below(32)).min(data.len() - start);
                data.drain(start..start + len);
            }
            // duplicate a chunk in place
            6 => {
                let start = rng.below(data.len());
                let len = (1 + rng.below(32)).min(data.len() - start);
                let chunk: Vec<u8> = data[start..start + len].to_vec();
                let at = rng.below(data.len() + 1);
                data.splice(at..at, chunk);
            }
            // nudge a 0xFF marker prefix: mutate the byte after some 0xFF
            7 => {
                let ffs: Vec<usize> = data
                    .iter()
                    .enumerate()
                    .filter(|(_, &b)| b == 0xFF)
                    .map(|(i, _)| i)
                    .collect();
                if let Some(&i) = ffs.get(rng.below(ffs.len().max(1))) {
                    if i + 1 < data.len() {
                        data[i + 1] = rng.below(256) as u8;
                    }
                }
            }
            // tweak a plausible big-endian length field (the two bytes
            // after a marker) to lie about segment size
            _ => {
                let i = rng.below(data.len());
                if i + 3 < data.len() && data[i] == 0xFF {
                    let lie = rng.below(0x10000) as u16;
                    data[i + 2] = (lie >> 8) as u8;
                    data[i + 3] = (lie & 0xFF) as u8;
                } else {
                    let j = rng.below(data.len());
                    data[j] = data[j].wrapping_add(0x80);
                }
            }
        }
    }
    data
}

/// Fuzz `decode_to_coefficients` with mutated corpus JPEGs.
pub fn fuzz_decoder(iters: usize, seed: u64) -> FuzzReport {
    let seeds: Vec<Vec<u8>> = corpus::corpus().into_iter().map(|e| e.bytes).collect();
    let mut rng = Rng::new(seed);
    let mut report = FuzzReport {
        target: "decoder",
        iters,
        ok: 0,
        typed_err: 0,
        panics: Vec::new(),
    };
    for it in 0..iters {
        let base = &seeds[rng.below(seeds.len())];
        let input = mutate(&mut rng, base, &seeds);
        match catch_unwind(AssertUnwindSafe(|| decode_to_coefficients(&input))) {
            Ok(Ok(_)) => report.ok += 1,
            Ok(Err(_)) => report.typed_err += 1,
            Err(payload) => report.panics.push((it, panic_message(payload))),
        }
    }
    report
}

/// Fuzz the wire frame parser with mutated valid frames (requests, stats
/// requests, and multi-frame concatenations), draining each stream the
/// way the listener does.
pub fn fuzz_wire(iters: usize, seed: u64) -> FuzzReport {
    let jpegs: Vec<Vec<u8>> = corpus::corpus().into_iter().map(|e| e.bytes).collect();
    // valid frame seeds: single requests, a stats scrape, a pipelined pair
    let mut seeds: Vec<Vec<u8>> = Vec::new();
    for (i, j) in jpegs.iter().take(4).enumerate() {
        seeds.push(
            protocol::encode_request(i as u64 + 1, 50_000, 75, j)
                .expect("valid request encodes"),
        );
    }
    seeds.push(protocol::encode_stats_request(99).expect("valid stats encodes"));
    let mut pair = seeds[0].clone();
    pair.extend_from_slice(&seeds[4]);
    seeds.push(pair);

    let mut rng = Rng::new(seed);
    let mut report = FuzzReport {
        target: "wire",
        iters,
        ok: 0,
        typed_err: 0,
        panics: Vec::new(),
    };
    for it in 0..iters {
        let base = &seeds[rng.below(seeds.len())];
        let input = mutate(&mut rng, base, &seeds);
        let drained = catch_unwind(AssertUnwindSafe(|| {
            let mut cur = Cursor::new(input.as_slice());
            let mut frames = 0usize;
            loop {
                match protocol::read_incoming(&mut cur) {
                    Ok(Some(_)) => frames += 1,
                    Ok(None) => return Ok(frames),
                    Err(e) => return Err(e),
                }
            }
        }));
        match drained {
            Ok(Ok(_)) => report.ok += 1,
            Ok(Err(_)) => report.typed_err += 1,
            Err(payload) => report.panics.push((it, panic_message(payload))),
        }
    }
    report
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mutation_is_deterministic() {
        let seeds: Vec<Vec<u8>> =
            corpus::corpus().into_iter().take(3).map(|e| e.bytes).collect();
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..50 {
            assert_eq!(
                mutate(&mut a, &seeds[0], &seeds),
                mutate(&mut b, &seeds[0], &seeds)
            );
        }
    }

    #[test]
    fn decoder_smoke_no_panics() {
        let r = fuzz_decoder(150, 1);
        assert_eq!(r.iters, 150);
        assert!(r.panics.is_empty(), "panics: {:?}", r.panics);
        assert!(r.typed_err > 0, "mutations should trip typed errors");
    }

    #[test]
    fn wire_smoke_no_panics() {
        let r = fuzz_wire(150, 2);
        assert!(r.panics.is_empty(), "panics: {:?}", r.panics);
        assert!(r.ok + r.typed_err == 150);
    }

    #[test]
    fn report_line_is_greppable() {
        let r = fuzz_decoder(10, 3);
        let line = r.to_string();
        assert!(line.starts_with("fuzz decoder: iters=10 "));
        assert!(line.contains("panics=0"), "{line}");
    }
}
