//! Unified telemetry for the serving stack: a central metrics
//! [`Registry`] of lock-free instruments, Prometheus-style text
//! exposition (rendered by [`Registry::render`], parsed back by
//! [`Scrape`]), and sampled per-request [`Tracer`] spans.
//!
//! Producers (pipeline, socket front end, coordinator, plan executor)
//! register instruments at construction and record through `Arc`
//! handles; consumers scrape one of three ways — the wire protocol's
//! `Stats` frame (`repro serve stats --remote`), the periodic
//! `--metrics-dump` file, or in-process `snapshot()` views that are
//! now read-only projections of the same registry.

pub mod expose;
pub mod registry;
pub mod trace;

pub use expose::Scrape;
pub use registry::{Counter, Gauge, Histogram, Registry};
pub use trace::Tracer;
