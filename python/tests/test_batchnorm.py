"""Paper §4.3: JPEG-domain batch normalization and its two theorems."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import jpeg_ops as jo, layers as L

QFLAT = jnp.asarray(jo.QTABLE_FLAT)


def rand(seed, n=6, c=3, h=16, w=16):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, c, h, w)).astype(np.float32))


class TestMeanVarianceTheorem:
    def test_theorem2(self):
        """Var[X] = E[Y^2] for zero-mean X (orthonormal DCT)."""
        rng = np.random.default_rng(0)
        x = rng.normal(size=64)
        x -= x.mean()
        y = jo.dct_matrix_2d() @ x
        assert abs(np.mean(y ** 2) - np.var(x)) < 1e-9

    def test_second_moment_via_parseval(self):
        """E[x^2] over a block = ||Y||^2 / 64 (the BN formulation)."""
        rng = np.random.default_rng(1)
        x = rng.normal(size=64)
        y = jo.dct_matrix_2d() @ x
        assert abs(np.mean(x ** 2) - np.sum(y ** 2) / 64) < 1e-9


class TestJpegBatchNorm:
    @pytest.mark.parametrize("training", [True, False])
    def test_matches_spatial(self, training):
        x = rand(2)
        c = jo.encode(x, QFLAT)
        g = jnp.asarray(np.random.default_rng(3).uniform(0.5, 2, 3).astype(np.float32))
        b = jnp.asarray(np.random.default_rng(4).normal(size=3).astype(np.float32))
        rm = jnp.asarray(np.random.default_rng(5).normal(size=3).astype(np.float32))
        rv = jnp.asarray(np.random.default_rng(6).uniform(0.5, 2, 3).astype(np.float32))
        ys, rms, rvs = L.batch_norm(x, g, b, rm, rv, training=training)
        cj, rmj, rvj = L.jpeg_batch_norm(c, QFLAT, g, b, rm, rv, training=training)
        yj = jo.decode(cj, QFLAT)
        np.testing.assert_allclose(ys, yj, atol=1e-4)
        np.testing.assert_allclose(rms, rmj, atol=1e-5)
        np.testing.assert_allclose(rvs, rvj, atol=1e-4)

    def test_lossy_table(self):
        q = jnp.asarray(jo.quality_scale(jo.ANNEX_K_LUMA, 60))
        x = rand(7)
        c = jo.encode(x, q)
        g = jnp.ones(3)
        b = jnp.zeros(3)
        rm, rv = jnp.zeros(3), jnp.ones(3)
        ys, _, _ = L.batch_norm(x, g, b, rm, rv, training=True)
        cj, _, _ = L.jpeg_batch_norm(c, q, g, b, rm, rv, training=True)
        np.testing.assert_allclose(ys, jo.decode(cj, q), atol=1e-3)

    def test_centering_zeroes_batch_dc_mean(self):
        """With gamma=1, beta=0 the normalized DC coefficients must have
        zero mean over the batch (the paper's set-(0,0)-to-zero step)."""
        x = rand(8)
        c = jo.encode(x, QFLAT)
        cj, _, _ = L.jpeg_batch_norm(
            c, QFLAT, jnp.ones(3), jnp.zeros(3), jnp.zeros(3), jnp.ones(3),
            training=True)
        dc_mean = np.array(jnp.mean(cj[..., 0], axis=(0, 2, 3)))
        np.testing.assert_allclose(dc_mean, 0, atol=1e-4)

    def test_beta_moves_only_dc(self):
        """Adding beta is a DC-only operation (paper §4.3)."""
        x = rand(9)
        c = jo.encode(x, QFLAT)
        args = (QFLAT, jnp.ones(3), jnp.zeros(3), jnp.zeros(3), jnp.ones(3))
        c0, _, _ = L.jpeg_batch_norm(c, *args, training=True)
        beta = jnp.asarray(np.array([1.0, -2.0, 0.5], np.float32))
        c1, _, _ = L.jpeg_batch_norm(
            c, QFLAT, jnp.ones(3), beta, jnp.zeros(3), jnp.ones(3),
            training=True)
        diff = np.array(c1 - c0)
        np.testing.assert_allclose(diff[..., 1:], 0, atol=1e-5)
        assert np.abs(diff[..., 0]).max() > 0.1

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 1000), n=st.integers(2, 8), c=st.integers(1, 4))
    def test_hypothesis_training_mode(self, seed, n, c):
        x = rand(seed, n=n, c=c)
        co = jo.encode(x, QFLAT)
        g, b = jnp.ones(c), jnp.zeros(c)
        rm, rv = jnp.zeros(c), jnp.ones(c)
        ys, _, _ = L.batch_norm(x, g, b, rm, rv, training=True)
        cj, _, _ = L.jpeg_batch_norm(co, QFLAT, g, b, rm, rv, training=True)
        np.testing.assert_allclose(ys, jo.decode(cj, QFLAT), atol=1e-3)


class TestGlobalAvgPool:
    def test_matches_spatial(self):
        x = rand(10, h=32, w=32)
        c = jo.encode(x, QFLAT)
        np.testing.assert_allclose(
            L.global_avg_pool(x), L.jpeg_global_avg_pool(c, QFLAT), atol=1e-5)

    def test_single_block_direct_read(self):
        """Paper Figure 2: for a 1x1-block map GAP is one DC read."""
        x = rand(11, h=8, w=8)
        c = jo.encode(x, QFLAT)
        expect = np.array(c)[..., 0, 0, 0] * float(QFLAT[0]) / 8.0
        np.testing.assert_allclose(
            L.jpeg_global_avg_pool(c, QFLAT), expect, atol=1e-6)
